"""Flow-serving tests: concurrency determinism, coalescing, the
netlist-delta fast path, bounded LRU caches and eviction safety.

The server's whole contract is "throughput construct, never a numerics
one": every record a future resolves to must be bit-identical to the
single-request reference ``flow.pack_and_analyze(net, arch,
seeds=(seed,))`` — under concurrency, coalescing, priority reordering,
cache eviction mid-flight, and both delta paths.
"""
from __future__ import annotations

import asyncio
import copy

import numpy as np
import pytest

from repro.core import flow, plan
from repro.core.circuits import kratos_gemm, sha_like, vtr_mixed
from repro.core.flow import _METRIC_KEYS, pack_and_analyze
from repro.core.netlist import Netlist
from repro.core.repack import cluster_delta, pack_prefix, repack
from repro.core.serve_flow import (FlowRequest, FlowServer, serve_requests)


@pytest.fixture(autouse=True)
def _fresh_caches():
    plan.clear_caches()
    plan.reset_cache_stats()
    yield
    plan.clear_caches()


def _nets():
    return [kratos_gemm(m=4, n=4, width=5, sparsity=0.5),
            sha_like(rounds=1),
            vtr_mixed(logic_nodes=100, adders=2)]


def _assert_record_matches(rec: dict, net: Netlist, arch: str, seed: int):
    ref = pack_and_analyze(net, arch, seeds=(seed,))
    for k in _METRIC_KEYS:
        assert rec[k] == ref[k], (net.name, arch, k, rec[k], ref[k])


# ---------------------------------------------------------------------------
# LRU cache layer (repro.core.plan)
# ---------------------------------------------------------------------------


def test_cache_lru_order_and_counters():
    c = plan.Cache("t", cap=2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1          # refreshes "a" — "b" is now LRU
    c.put("c", 3)                   # evicts "b"
    assert "a" in c and "c" in c and "b" not in c
    assert c.get("b") is None
    st = c.stats()
    assert st == {"size": 2, "cap": 2, "hits": 1, "misses": 1,
                  "evictions": 1, "hit_rate": 0.5}
    # __contains__ is a probe: never counts, never refreshes
    _ = "a" in c
    assert c.stats()["hits"] == 1
    c.clear()                       # entries drop, lifetime counters stay
    assert c.stats() == {"size": 0, "cap": 2, "hits": 1, "misses": 1,
                         "evictions": 1, "hit_rate": 0.5}
    c.reset_stats()
    assert c.stats()["hits"] == 0
    assert c.stats()["hit_rate"] == 0.0    # derived: no lookups yet


def test_cache_resize_and_registry_knobs():
    cache = plan.register_cache("test_resize_knob", cap=8)
    for i in range(8):
        cache.put(i, i)
    plan.set_cache_cap("test_resize_knob", 3)
    assert len(cache) == 3 and cache.cap == 3
    assert cache.stats()["evictions"] == 5
    assert set(cache.keys()) == {5, 6, 7}  # LRU evicted first
    with pytest.raises(KeyError, match="test_resize_knob"):
        plan.set_cache_cap("no_such_cache", 4)
    with pytest.raises(ValueError):
        cache.resize(0)
    assert "test_resize_knob" in plan.cache_stats()


def test_prefix_eviction_forces_clean_repack():
    """Evicting a ClusterPlan prefix (LRU pressure) must force a fresh
    prefix + re-pack that is byte-identical — eviction is a throughput
    event, never a correctness one."""
    net = kratos_gemm(m=4, n=4, width=5, sparsity=0.5)
    cache = plan.register_cache("pack_prefix")
    old_cap = cache.cap
    try:
        prefix = pack_prefix(net, seed=0)
        cache.put((net.content_digest(), 0), prefix)
        p0 = repack(prefix, flow._arch("baseline"))
        plan.set_cache_cap("pack_prefix", 1)
        # stream unrelated prefixes through to evict the original
        other = sha_like(rounds=1)
        cache.put((other.content_digest(), 0), pack_prefix(other, seed=0))
        assert (net.content_digest(), 0) not in cache
        # a re-pack from a *fresh* prefix must be byte-identical
        p1 = repack(pack_prefix(net, seed=0), flow._arch("baseline"))
        assert cluster_delta(p0, p1)["n_changed"] == 0
        from repro.core.timing import analyze

        assert analyze(p0) == analyze(p1)
    finally:
        plan.set_cache_cap("pack_prefix", old_cap)


# ---------------------------------------------------------------------------
# serving: coalescing, priority, determinism
# ---------------------------------------------------------------------------


def test_serve_matches_serial_flow():
    nets = _nets()
    reqs = [FlowRequest(net, arch, analyses=("area", "timing"), seed=0)
            for net in nets for arch in ("baseline", "dd5")]
    results = serve_requests(reqs)
    assert len(results) == len(reqs)
    for req, res in zip(reqs, results):
        assert res.net == req.net.name
        _assert_record_matches(res.record, req.net, req.arch, req.seed)
        # per-stage wall attribution rides every result
        stages = res.walls["stages"]
        assert {"repack_s", "timing_s", "total_s"} <= set(stages)
        assert res.walls["total_s"] >= res.walls["service_s"] >= 0.0


def test_serve_coalesces_identical_requests():
    net = kratos_gemm(m=4, n=4, width=5, sparsity=0.5)

    async def main():
        server = FlowServer(batch_window_s=0.01)
        rs = await asyncio.gather(*(server.submit(
            FlowRequest(net, "baseline")) for _ in range(4)))
        await server.aclose()
        return rs, dict(server.stats)

    rs, stats = asyncio.run(main())
    # all four landed in one batch, one job served them all
    assert all(r.batch["id"] == rs[0].batch["id"] for r in rs)
    assert all(r.batch["n_shared"] == 4 for r in rs)
    assert stats["n_jobs"] == 1 and stats["n_coalesced"] == 3
    assert all(r.record is rs[0].record for r in rs)  # shared, not copied
    _assert_record_matches(rs[0].record, net, "baseline", 0)


def test_serve_priority_order_under_small_batches():
    """With max_batch=1 every batch holds one request; the drain order
    must be (-priority, arrival), so the high-priority latecomer is
    served in an earlier batch than the low-priority head."""
    nets = _nets()

    async def main():
        server = FlowServer(batch_window_s=0.02, max_batch=1)
        futs = [server.submit_nowait(FlowRequest(nets[0], "baseline",
                                                 priority=0)),
                server.submit_nowait(FlowRequest(nets[1], "baseline",
                                                 priority=5)),
                server.submit_nowait(FlowRequest(nets[2], "baseline",
                                                 priority=1))]
        rs = await asyncio.gather(*futs)
        await server.aclose()
        return rs

    r0, r1, r2 = asyncio.run(main())
    assert r1.batch["id"] < r2.batch["id"] < r0.batch["id"]
    for r, net in zip((r0, r1, r2), nets):
        _assert_record_matches(r.record, net, "baseline", 0)


def test_serve_concurrent_clients_with_midflight_eviction():
    """N asyncio clients stream a mixed workload while another task
    repeatedly clears/shrinks the shared caches mid-flight — every
    result must stay byte-identical to the serial reference."""
    nets = _nets()
    pool = [(net, arch) for net in nets for arch in ("baseline", "dd5")]
    n_clients, n_requests = 4, 16
    results: list = [None] * n_requests

    async def main():
        server = FlowServer(batch_window_s=0.001)

        async def client(ci):
            for j in range(ci, n_requests, n_clients):
                net, arch = pool[j % len(pool)]
                results[j] = await server.submit(
                    FlowRequest(net, arch, seed=0))

        async def evictor():
            # forced eviction between batches: full clears plus LRU
            # pressure on the pack/timing stores
            for _ in range(6):
                await asyncio.sleep(0.002)
                plan.clear_caches()
                plan.set_cache_cap("serve_packs", 1)
                plan.set_cache_cap("serve_timing", 1)

        try:
            await asyncio.gather(evictor(),
                                 *(client(c) for c in range(n_clients)))
        finally:
            await server.aclose()
            plan.set_cache_cap("serve_packs", 256)
            plan.set_cache_cap("serve_timing", 2048)

    asyncio.run(main())
    refs: dict = {}
    for j in range(n_requests):
        net, arch = pool[j % len(pool)]
        key = (net.name, arch)
        if key not in refs:
            refs[key] = pack_and_analyze(net, arch, seeds=(0,))
        for k in _METRIC_KEYS:
            assert results[j].record[k] == refs[key][k]


# ---------------------------------------------------------------------------
# netlist-delta fast path
# ---------------------------------------------------------------------------


def test_pack_digest_ignores_truth_tables_only():
    net = kratos_gemm(m=4, n=4, width=5, sparsity=0.5)
    tt_edit = copy.deepcopy(net)
    tt_edit.lut_tt[0] ^= 0xFFFF
    assert tt_edit.content_digest() != net.content_digest()
    assert tt_edit.pack_digest() == net.pack_digest()
    structural = kratos_gemm(m=4, n=4, width=6, sparsity=0.5)
    assert structural.pack_digest() != net.pack_digest()


def test_serve_delta_tt_only_reuses_pack_and_timing():
    net = kratos_gemm(m=4, n=4, width=5, sparsity=0.5)
    base_digest = net.content_digest()
    tt_edit = copy.deepcopy(net)
    tt_edit.lut_tt[0] ^= 0xFFFF

    async def main():
        server = FlowServer()
        r0 = await server.submit(FlowRequest(net, "baseline"))
        r1 = await server.submit(FlowRequest(tt_edit, "baseline",
                                             base_digest=base_digest))
        await server.aclose()
        return r0, r1, dict(server.stats)

    r0, r1, stats = asyncio.run(main())
    assert r1.delta["mode"] == "tt_only"
    assert r1.delta["n_changed"] == 0
    assert r1.batch["pack_cached"] and r1.batch["timing_cached"]
    assert stats["n_delta_pack_reuse"] == 1
    # the reused record is still bit-identical to a fresh serial flow of
    # the *edited* netlist (tt independence of pack + timing)
    _assert_record_matches(r1.record, tt_edit, "baseline", 0)
    assert r1.record["critical_path_ps"] == r0.record["critical_path_ps"]


def test_serve_delta_structural_attribution():
    net = kratos_gemm(m=4, n=4, width=5, sparsity=0.5)
    edited = kratos_gemm(m=4, n=4, width=6, sparsity=0.5)
    base_digest = net.content_digest()

    async def main():
        server = FlowServer()
        await server.submit(FlowRequest(net, "baseline"))
        r = await server.submit(FlowRequest(edited, "baseline",
                                            base_digest=base_digest))
        await server.aclose()
        return r

    r = asyncio.run(main())
    assert r.delta["mode"] == "structural"
    assert r.delta["n_lbs_base"] >= 1 and r.delta["n_lbs_new"] >= 1
    assert 0 <= r.delta["unchanged_frac"] <= 1
    _assert_record_matches(r.record, edited, "baseline", 0)


def test_serve_delta_dirty_set_incremental_path():
    """A single-LUT fanin rewire with ``base_digest`` set rides the
    dirty-set path end to end: incremental repack, dirty-column IR
    patch, scoped per-cluster proof — and the served record is still
    bit-identical to a fresh serial flow of the edited netlist."""
    import random

    from repro.core.alm import ARCHS
    from repro.core.edits import (clone_netlist, edit_rewire_fanin,
                                  safe_rewire_sources)
    from repro.core.repack import (pack_prefix_delta, repack_delta,
                                   repack_with_log)

    net = kratos_gemm(m=4, n=4, width=5, sparsity=0.5)
    arch = ARCHS["dd5"]
    prefix = pack_prefix(net, seed=0)
    _, log = repack_with_log(prefix, arch)
    # probe for an edit that stays on the incremental path (some rewires
    # legally fall back at the absorption/pairing gates)
    rng = random.Random(7)
    edited = None
    for _ in range(50):
        li = rng.randrange(net.n_luts)
        srcs = safe_rewire_sources(net, li)
        if not srcs:
            continue
        pin = rng.randrange(len(net.lut_inputs[li]))
        src = rng.choice(srcs)
        if net.lut_inputs[li][pin] == src:
            continue
        cand = clone_netlist(net)
        edit_rewire_fanin(cand, li, pin, src)
        np_, pinfo = pack_prefix_delta(prefix, cand, base_log=log)
        if np_ is None or pinfo["mode"] != "incremental":
            continue
        _, rinfo = repack_delta(np_, log, arch,
                                dirty_atoms=pinfo["dirty_atoms"])
        if rinfo["mode"] == "incremental":
            edited = cand
            break
    assert edited is not None, "no incremental-path rewire found"
    plan.clear_caches()

    async def main():
        server = FlowServer()
        base = await server.submit(FlowRequest(net, "dd5"))
        r = await server.submit(FlowRequest(edited, "dd5",
                                            base_digest=base.digest))
        await server.aclose()
        return r, dict(server.stats)

    r, stats = asyncio.run(main())
    assert r.delta["mode"] == "structural"
    assert r.delta["repack"]["mode"] == "incremental"
    assert r.delta["repack"]["n_frozen_lbs"] >= 1
    assert r.delta["verify"]["method"] == "symbolic_scoped"
    assert r.delta["verify"]["equivalent"] is True
    # moved-vs-re-clustered attribution is present and partitions
    assert (r.delta["n_frozen"] + r.delta["n_moved"]
            + r.delta["n_reclustered"]) >= r.delta["n_lbs_new"] - 1
    assert stats["n_delta_incremental"] == 1
    assert stats["n_delta_fallback"] == 0
    assert stats["n_verify_scoped"] == 1 and stats["n_verify_full"] == 0
    _assert_record_matches(r.record, edited, "dd5", 0)


def test_cluster_delta_identical_and_disjoint():
    net = kratos_gemm(m=4, n=4, width=5, sparsity=0.5)
    arch = flow._arch("baseline")
    p = repack(pack_prefix(net, seed=0), arch)
    d = cluster_delta(p, p)
    assert d["n_changed"] == 0 and d["unchanged_frac"] == 1.0
    other = repack(pack_prefix(sha_like(rounds=1), seed=0), arch)
    d2 = cluster_delta(p, other)
    assert d2["n_changed"] == max(d2["n_lbs_base"], d2["n_lbs_new"])


# ---------------------------------------------------------------------------
# eval analysis + warm="auto" cost model
# ---------------------------------------------------------------------------


def test_serve_eval_matches_oracle_and_memoizes():
    net = sha_like(rounds=1)
    lanes = flow.random_lanes(net, 2, seed=0)
    ref = flow.evaluate_netlist(net, lanes, 2)

    async def main():
        server = FlowServer()
        r0 = await server.submit(FlowRequest(net, "baseline",
                                             analyses=("eval",),
                                             n_lane_words=2))
        r1 = await server.submit(FlowRequest(net, "dd5",
                                             analyses=("eval",),
                                             n_lane_words=2))
        await server.aclose()
        return r0, r1, dict(server.stats)

    r0, r1, stats = asyncio.run(main())
    for name, bus in net.pos.items():
        want = ref[np.asarray(bus, dtype=np.int64)]
        assert np.array_equal(r0.analyses["eval"][name], want)
        assert np.array_equal(r1.analyses["eval"][name], want)
    # eval is arch-independent: the second request (different arch, same
    # lane config) must be a memo hit, and eval-only requests never pack
    assert stats["n_eval_hits"] == 1
    assert r1.record is None and "eval" in r1.analyses
    assert r0.record is None


def test_eval_warm_auto_derives_from_actual_runs():
    """The cost model's warm='auto' must charge a compile for a program
    that never ran and none for one that did — derived from the
    registry's run markers, not caller assertion."""
    nets = [sha_like(rounds=1), kratos_gemm(m=4, n=4, width=5,
                                            sparsity=0.5)]
    model_cold = flow.eval_mode_cost_model(nets, warm="auto",
                                           n_lane_words=2)
    assert model_cold["n_cold_programs_grouped"] >= 1
    assert model_cold["n_cold_programs_per_circuit"] == len(nets)
    # run the grouped path once; its program signature is now marked
    lanes = [flow.random_lanes(n, 2, seed=0) for n in nets]
    flow.evaluate_suite(nets, lanes, 2, mode="grouped")
    model_warm = flow.eval_mode_cost_model(nets, warm="auto",
                                           n_lane_words=2)
    assert model_warm["n_cold_programs_grouped"] == 0
    # the per-circuit programs still never ran
    assert model_warm["n_cold_programs_per_circuit"] == len(nets)
    # forced overrides still win over the markers
    forced = flow.eval_mode_cost_model(nets, warm=False, n_lane_words=2)
    assert forced["n_cold_programs_grouped"] >= 1
    with pytest.raises(ValueError, match="warm"):
        flow.eval_mode_cost_model(nets, warm="yes")


# ---------------------------------------------------------------------------
# server surface
# ---------------------------------------------------------------------------


def test_serve_request_validation_and_close():
    net = sha_like(rounds=1)
    with pytest.raises(ValueError, match="unknown analyses"):
        FlowRequest(net, "baseline", analyses=("timing", "power"))
    with pytest.raises(ValueError, match="no analyses"):
        FlowRequest(net, "baseline", analyses=())
    with pytest.raises(ValueError, match="backend"):
        FlowServer(timing_backend="fpga")

    async def main():
        server = FlowServer(batch_window_s=10.0)  # never fires in time
        fut = server.submit_nowait(FlowRequest(net, "baseline"))
        await asyncio.sleep(0)
        await server.aclose()
        with pytest.raises(RuntimeError, match="closed"):
            await fut

    asyncio.run(main())


def test_serve_cache_stats_surface():
    net = sha_like(rounds=1)

    async def main():
        server = FlowServer()
        await server.submit(FlowRequest(net, "baseline"))
        await server.submit(FlowRequest(net, "baseline"))
        st = server.cache_stats()
        await server.aclose()
        return st, dict(server.stats)

    st, stats = asyncio.run(main())
    for name in ("serve_packs", "serve_timing", "serve_programs",
                 "serve_digests", "pack_prefix"):
        assert name in st and st[name]["cap"] >= 1
    # second identical request (separate batch): pack + timing memo hits
    assert stats["n_pack_hits"] == 1
    assert stats["n_timing_hits"] == 1
    assert st["serve_packs"]["hits"] >= 1


def test_serve_numpy_backend_parity():
    nets = _nets()[:2]
    reqs = [FlowRequest(net, arch) for net in nets
            for arch in ("baseline", "dd6")]
    results = serve_requests(reqs, timing_backend="numpy")
    for req, res in zip(reqs, results):
        _assert_record_matches(res.record, req.net, req.arch, req.seed)
