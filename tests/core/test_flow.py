"""Property-style tests for the unified flow pipeline: envelope-grouped /
width-bucketed evaluation must be bit-exact against the ``eval_netlist``
Python oracle and against the old single-envelope path, on random circuits
and across all three architectures."""
import random

import numpy as np
import pytest

from repro.core import flow
from repro.core.alm import ARCHS
from repro.core.circuits import kratos_gemm, sha_like
from repro.core.equiv import reelaborate
from repro.core.eval_jax import (eval_netlists_batched_jax,
                                 group_plans_by_envelope,
                                 grouping_padded_value_rows, plan_netlist)
from repro.core.netlist import CONST0, CONST1, Netlist
from repro.core.packing import pack

from _hypothesis_shim import given, settings, st


def random_netlist(seed: int) -> Netlist:
    """LUT cloud + carry chains + post-chain logic (deep enough to have a
    non-trivial level-width profile)."""
    rng = random.Random(seed)
    net = Netlist(f"rand{seed}")
    pool = list(net.add_pi_bus("in", rng.randint(8, 16)))
    for _ in range(rng.randint(10, 40)):
        k = rng.randint(1, 6)
        ins = rng.sample(pool, min(k, len(pool)))
        pool.append(net.add_lut(tuple(ins), rng.getrandbits(1 << len(ins))))
    for c in range(rng.randint(1, 3)):
        w = rng.randint(2, 10)
        a = [rng.choice(pool) for _ in range(w)]
        b = [rng.choice(pool) for _ in range(w)]
        cin = rng.choice([CONST0, CONST1, rng.choice(pool)])
        sums, cout = net.add_chain(a, b, cin=cin,
                                   want_cout=rng.random() < 0.5)
        pool.extend(sums)
        net.set_po_bus(f"s{c}", sums)
        if cout is not None:
            net.set_po_bus(f"c{c}", [cout])
    for _ in range(rng.randint(5, 15)):
        k = rng.randint(2, 5)
        ins = rng.sample(pool, min(k, len(pool)))
        pool.append(net.add_lut(tuple(ins), rng.getrandbits(1 << len(ins))))
    net.set_po_bus("po", pool[-min(8, len(pool)):])
    return net.sweep()


def _oracle_po_match(net, lanes, vals, n_lane_words):
    return flow.oracle_check(net, lanes, vals, n_lane_words)


@given(seed=st.integers(min_value=0, max_value=10_000))
@settings(max_examples=4, deadline=None)
def test_bucketed_eval_matches_oracle(seed):
    """Single-circuit bucketed multi-scan == Python oracle (property).
    The jnp kernel path keeps the fuzz loop's compile cost low; the
    pallas kernel itself is proven in test_eval_jax / test_kernels."""
    net = random_netlist(seed)
    lanes = flow.random_lanes(net, 2, seed=seed)
    vals = flow.evaluate_netlist(net, lanes, 2, use_pallas=False)
    assert _oracle_po_match(net, lanes, vals, 2)


@pytest.mark.slow
@given(seed=st.integers(min_value=0, max_value=10_000),
       max_groups=st.integers(min_value=1, max_value=3))
@settings(max_examples=3, deadline=None)
def test_grouped_eval_matches_oracle_and_single_envelope(seed, max_groups):
    """Envelope-grouped suite eval == oracle == old single-envelope path,
    for any group budget (property)."""
    nets = [random_netlist(seed + i) for i in range(4)]
    lanes = [flow.random_lanes(n, 2, seed=seed + 100 + i)
             for i, n in enumerate(nets)]
    outs, stats = flow.evaluate_suite(nets, lanes, 2, max_groups=max_groups,
                                      use_pallas=False)
    assert stats["n_groups"] <= max_groups
    # the old single-worst-case-envelope path: one group, one bucket
    outs_single = eval_netlists_batched_jax(nets, lanes, 2, max_groups=1,
                                            max_buckets=1,
                                            use_pallas=False)
    for net, ln, got, ref in zip(nets, lanes, outs, outs_single):
        assert np.array_equal(got, ref), net.name
        assert _oracle_po_match(net, ln, got, 2)


@pytest.mark.parametrize("arch_name", ["baseline", "dd5", "dd6"])
def test_grouped_eval_of_reelaborations_matches_oracle(arch_name):
    """The suite-scale use case: per-arch re-elaborated physical netlists
    evaluated as one grouped program, proven against the oracle."""
    nets = [random_netlist(s) for s in (3, 7, 11)]
    phys = [reelaborate(pack(n, ARCHS[arch_name], seed=0)).phys
            for n in nets]
    lanes = [flow.random_lanes(p, 1, seed=i) for i, p in enumerate(phys)]
    outs, stats = flow.evaluate_suite(phys, lanes, 1, max_groups=2)
    assert stats["n_groups"] <= 2
    for p, ln, got in zip(phys, lanes, outs):
        assert _oracle_po_match(p, ln, got, 1)


def test_suite_compiles_to_few_groups():
    """A real mixed suite clusters into <= 4 envelope groups (the
    one-jit-program-per-group property of the suite-scale flow)."""
    nets = [kratos_gemm(m=3, n=3, width=4, sparsity=0.3),
            kratos_gemm(m=4, n=4, width=5, sparsity=0.5, seed=2),
            sha_like(rounds=1),
            random_netlist(5),
            random_netlist(9)]
    plans = [plan_netlist(n) for n in nets]
    groups = group_plans_by_envelope(plans, max_groups=4)
    assert len(groups) <= 4
    assert sorted(i for g in groups for i in g) == list(range(len(nets)))


def test_size_aware_grouping_isolates_giant_value_buffer():
    """A circuit with a huge signal count but a tiny level envelope must
    not be co-located with small circuits (its group mates would pad
    their value buffers to the giant's row count).  The signal-count
    merge term isolates it; the volume-only cost (signal_weight=0) is
    the old behavior and groups it."""
    giant = Netlist("giant")
    pis = giant.add_pi_bus("in", 3000)          # many signals, ...
    o = giant.add_lut((pis[0], pis[1], pis[2]), 0b10010110)
    giant.set_po_bus("po", [o])                 # ... near-empty envelope
    smalls = [random_netlist(s) for s in (1, 2, 3)]
    plans = [plan_netlist(n) for n in [giant] + smalls]
    g_old = group_plans_by_envelope(plans, max_groups=2, signal_weight=0.0)
    g_new = group_plans_by_envelope(plans, max_groups=2)
    assert [0] in g_new, f"giant not isolated: {g_new}"
    rows_old = grouping_padded_value_rows(plans, g_old)
    rows_new = grouping_padded_value_rows(plans, g_new)
    assert rows_new["padded_rows"] < rows_old["padded_rows"]
    assert rows_new["padded_rows"] >= rows_new["real_rows"]
    # grouping still covers every plan exactly once
    assert sorted(i for g in g_new for i in g) == list(range(len(plans)))


def test_bucketed_plan_cuts_padding_waste():
    """On a wide-then-narrow profile the bucketed plan must waste fewer
    padded rows than the single worst-case envelope."""
    net = kratos_gemm(m=4, n=4, width=5, sparsity=0.4)
    p = plan_netlist(net)
    real = p.real_luts + p.real_chain_bits
    padded_bucketed = p.padded_lut_rows + p.padded_chain_bits
    L, M, C, B = p.envelope
    padded_single = L * M + L * C * B
    assert 1 <= len(p.buckets) <= 3
    assert padded_bucketed < padded_single
    assert real <= padded_bucketed


def test_pack_and_analyze_matches_direct_flow():
    """flow.pack_and_analyze == seed-averaged pack+analyze by hand."""
    from repro.core.timing import analyze

    net = random_netlist(1)
    seeds = (0, 1)
    rec = flow.pack_and_analyze(net, "dd5", seeds=seeds)
    want = {}
    for s in seeds:
        r = analyze(pack(net, ARCHS["dd5"], seed=s))
        for k in ("alms", "area_mwta", "adp"):
            want[k] = want.get(k, 0.0) + r[k] / len(seeds)
    for k, v in want.items():
        assert rec[k] == pytest.approx(v)


def test_run_circuit_equiv_gate():
    """The flow's equivalence gate proves (and records) pack equivalence."""
    net = random_netlist(2)
    out = flow.run_circuit(net, ("baseline", "dd5"), seeds=(0,),
                           check_equiv=True)
    for arch, rec in out.items():
        assert rec["equivalent"], arch
        assert rec["equiv_method"] in ("symbolic", "simulate")


def test_ratios_vs_baseline():
    per_arch = {
        "baseline": {"area_mwta": 100.0, "critical_path_ps": 10.0,
                     "adp": 1000.0},
        "dd5": {"area_mwta": 80.0, "critical_path_ps": 11.0, "adp": 880.0},
    }
    r = flow.ratios_vs_baseline(per_arch)
    assert r == {"dd5": {"area_mwta": 0.8, "critical_path_ps": 1.1,
                         "adp": 0.88}}
