"""Algorithm 1 (strength DP) behaviour tests."""
import random

from repro.core.adder_tree import (_best_placement, _greedy_placement,
                                   count_stage_strength, reduce_binary)
from repro.core.netlist import Netlist
from repro.core.synth import Row


def _rows_shifted_dups(net, width=6, shifts=(0, 2, 4, 6)):
    x = net.add_pi_bus("x", width)
    return [Row(s, tuple(x)) for s in shifts]


def test_dp_prefers_duplicate_chains():
    """With 4 shifted copies the DP must pair (0,2),(4,6) — equal deltas —
    rather than e.g. (0,6),(2,4)."""
    net = Netlist()
    rows = _rows_shifted_dups(net)
    pairs, passthrough = _best_placement(net, rows, width_cap=None)
    assert not passthrough
    deltas = sorted(abs(rows[i].shift - rows[j].shift) for i, j in pairs)
    assert deltas == [2, 2], pairs


def test_dp_beats_adjacent_pairing_strength():
    net = Netlist()
    x = net.add_pi_bus("x", 6)
    # shifts chosen so adjacent pairing yields unequal deltas
    rows = [Row(s, tuple(x)) for s in (0, 1, 3, 4)]
    dp_pairs, _ = _best_placement(net, rows, None)
    h_dp = count_stage_strength(net, rows, dp_pairs)
    h_adj = count_stage_strength(net, rows, [(0, 1), (2, 3)])
    assert h_dp >= h_adj


def test_dp_odd_row_passthrough():
    net = Netlist()
    rows = _rows_shifted_dups(net, shifts=(0, 2, 4))
    pairs, passthrough = _best_placement(net, rows, None)
    assert len(pairs) == 1 and len(passthrough) == 1


def test_greedy_groups_same_bits():
    net = Netlist()
    x = net.add_pi_bus("x", 6)
    y = net.add_pi_bus("y", 6)
    rows = [Row(s, tuple(x)) for s in (0, 2, 4, 6)] + \
           [Row(s, tuple(y)) for s in (1, 3)]
    pairs, passthrough = _greedy_placement(rows)
    assert not passthrough
    for i, j in pairs:
        assert rows[i].bits == rows[j].bits  # never mixes x rows with y rows


def test_reduce_binary_single_row():
    net = Netlist()
    x = net.add_pi_bus("x", 4)
    r = reduce_binary(net, [Row(0, tuple(x))])
    assert r.bits == tuple(x)
    assert net.n_adders == 0


def test_reduce_binary_counts_less_than_naive():
    random.seed(3)
    for _ in range(5):
        shifts = sorted(random.sample(range(10), 6))
        net_a = Netlist()
        x = net_a.add_pi_bus("x", 8)
        reduce_binary(net_a, [Row(s, tuple(x)) for s in shifts],
                      use_dp=True, share=True)
        net_b = Netlist()
        x = net_b.add_pi_bus("x", 8)
        reduce_binary(net_b, [Row(s, tuple(x)) for s in shifts],
                      use_dp=False, share=False)
        assert net_a.n_adders <= net_b.n_adders
