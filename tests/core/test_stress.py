"""Stress-test behaviours (Fig. 9 / Table IV)."""
from repro.core.alm import BASELINE, DD5
from repro.core.circuits import kratos_gemm, sha_like
from repro.core.stress import (merge_netlists, packing_stress_circuit,
                               run_e2e_stress, run_packing_stress)
from repro.core.netlist import Netlist, bus_to_ints, eval_netlist
from repro.core.packing import pack


def test_stress_dd5_absorbs_luts_flat_area():
    res5 = run_packing_stress(DD5, n_adders=200, lut_counts=[0, 100, 200])
    res0 = run_packing_stress(BASELINE, n_adders=200, lut_counts=[0, 100, 200])
    # baseline area strictly grows; DD5 stays flat while absorbing
    assert res0[2]["alms"] > res0[0]["alms"]
    assert res5[1]["alms"] == res5[0]["alms"]
    assert res5[1]["concurrent"] == 100


def test_stress_saturation_in_paper_range():
    """Fig. 9: concurrency saturates around 60-85 % of the theoretical max."""
    res = run_packing_stress(DD5, n_adders=500, lut_counts=[500])
    frac = res[0]["concurrent"] / 500
    assert 0.5 <= frac <= 0.9, frac


def test_e2e_stress_dd5_fits_more():
    base = kratos_gemm(m=6, n=6, width=6, sparsity=0.5)
    sha = sha_like(rounds=1)
    res = run_e2e_stress(base, sha, [BASELINE, DD5], max_instances=24)
    assert res["dd5"]["instances"] > res["baseline"]["instances"]
    assert res["dd5"]["concurrent"] > 0


def test_merge_netlists_functional():
    n1 = Netlist("a")
    x = n1.add_pi_bus("x", 4)
    y = n1.add_pi_bus("y", 4)
    s, _ = n1.add_chain(list(x), list(y))
    n1.set_po_bus("s", s)
    merged = merge_netlists([n1, n1])
    assert len(merged.pis) == 16
    assert merged.n_adders == 8
    vals = {}
    for j, sg in enumerate(merged.pi_buses["i0_x"]):
        vals[sg] = 0b1 if j == 0 else 0   # x0 = 1
    for j, sg in enumerate(merged.pi_buses["i0_y"]):
        vals[sg] = 0b1 if j == 1 else 0   # y0 = 2
    for j, sg in enumerate(merged.pi_buses["i1_x"]):
        vals[sg] = 0b1 if j == 2 else 0   # x1 = 4
    for j, sg in enumerate(merged.pi_buses["i1_y"]):
        vals[sg] = 0b1 if j == 2 else 0   # y1 = 4
    r = eval_netlist(merged, vals, 1)
    assert bus_to_ints(r, merged.pos["i0_s"], 1)[0] == 3
    assert bus_to_ints(r, merged.pos["i1_s"], 1)[0] == 8


def test_stress_circuit_shapes():
    net = packing_stress_circuit(n_adders=100, n_luts=50)
    assert net.n_adders == 100
    assert net.n_luts == 50
