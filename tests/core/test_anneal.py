"""Simulated-annealing placement refinement: determinism, legality, the
never-worse-than-seed guarantee, timing-driven weighting, the placed
timing bit-identity it must preserve, and the registry cache contract.

The contract under test: ``place_ir(refine="anneal")`` returns a grid-
legal placement that is bit-deterministic per (netlist digest, arch
placement key, seed, refine mode), whose wirelength never exceeds the
analytic seed's; the placed vectorized timing path stays bit-identical
to :func:`repro.core.timing.analyze_placed_oracle` on annealed
placements at zero AND nonzero wire delays (the Fig-5/Table-III pins
survive refinement); and every annealer cache — refined placements in
``"placement"``, criticality weights in ``"criticality"`` — lives in the
unified :mod:`repro.core.plan` registry so one ``clear_caches()``
provably drops them (the PR-6 stale-placement regression, re-pinned for
the annealer).
"""
import numpy as np
import pytest

from repro.core.alm import ARCHS, make_arch
from repro.core.anneal import (ANNEAL_COUNTS, criticality_weights,
                               delay_signature, edge_criticality,
                               refine_placement)
from repro.core.circuit_ir import apply_placement
from repro.core.circuits import kratos_gemm
from repro.core.packing import pack
from repro.core.place import (PLACE_COUNTS, _routed_edges, place_ir,
                              placement_for)
from repro.core.plan import cache_stats, clear_caches
from repro.core.timing import analyze_oracle, analyze_placed_oracle
from repro.core.timing_vec import analyze_ir, build_suite_timing_program


def _wired(arch, w1=25.0, w2=40.0, wl=120.0, **kw):
    return make_arch(arch.name + "_wired", bypass_inputs=arch.bypass_inputs,
                     addmux_fanin=arch.addmux_fanin,
                     lut6=arch.concurrent_6lut,
                     t_wire_hop1=w1, t_wire_hop2=w2, t_wire_long=wl, **kw)


def _ir(net=None, arch=None):
    net = net or kratos_gemm(m=4, n=4, width=5, sparsity=0.5)
    arch = arch or ARCHS["dd5"]
    return pack(net, arch).lower_ir()


def _legal(pl, n_lbs):
    assert pl.grid_w * pl.grid_h >= n_lbs
    assert (pl.lb_x >= 0).all() and (pl.lb_x < pl.grid_w).all()
    assert (pl.lb_y >= 0).all() and (pl.lb_y < pl.grid_h).all()
    slots = set(zip(pl.lb_x.tolist(), pl.lb_y.tolist()))
    assert len(slots) == n_lbs, "overlapping LB slots after refinement"


def test_refined_placement_deterministic_legal_never_worse():
    arch = ARCHS["dd5"]
    ir = _ir(arch=arch)
    seed_pl = place_ir(ir, arch, seed=0)
    for backend in ("numpy", "jax"):
        a = place_ir(ir, arch, seed=0, refine="anneal", backend=backend)
        b = place_ir(ir, arch, seed=0, refine="anneal", backend=backend)
        assert np.array_equal(a.lb_x, b.lb_x)
        assert np.array_equal(a.lb_y, b.lb_y)
        _legal(a, ir.n_lbs)
        assert a.refine == "anneal"
        assert (a.grid_w, a.grid_h) == (seed_pl.grid_w, seed_pl.grid_h)
        assert a.wirelength(ir) <= seed_pl.wirelength(ir)
    # distinct seeds explore distinct trajectories
    c = place_ir(ir, arch, seed=1, refine="anneal")
    a = place_ir(ir, arch, seed=0, refine="anneal")
    assert not (np.array_equal(a.lb_x, c.lb_x)
                and np.array_equal(a.lb_y, c.lb_y))


def test_refinement_actually_improves_wirelength():
    """The annealer exists to beat the legalization-limited seed — on a
    real suite member it must strictly improve, not merely tie (the
    17-circuit geomean >= 5% gate lives in benchmarks/anneal_refine)."""
    arch = ARCHS["dd5"]
    ir = _ir(arch=arch)
    seed_pl = place_ir(ir, arch, seed=0)
    ann = place_ir(ir, arch, seed=0, refine="anneal")
    assert ann.wirelength(ir) < seed_pl.wirelength(ir)


def test_trivial_circuits_refine_to_seed():
    """<= 1 LB (or no routed edges): refinement is a no-op, not a crash."""
    from repro.core.circuits import vtr_mixed

    arch = ARCHS["dd5"]
    ir = pack(vtr_mixed(logic_nodes=8, adders=1), arch).lower_ir()
    assert ir.n_lbs == 1
    seed_pl = place_ir(ir, arch, seed=0)
    ann = refine_placement(ir, arch, seed_pl, seed=0)
    assert ann is seed_pl
    assert place_ir(ir, arch, seed=0, refine="anneal").n_lbs == 1


def test_placed_timing_bit_identical_on_annealed_placements():
    """Vectorized placed timing == placed Python oracle, bit for bit, on
    *annealed* placements — zero and nonzero wire delays, both timing
    backends (numpy walk + batched jax program)."""
    net = kratos_gemm(m=4, n=4, width=5, sparsity=0.5)
    for aname in ("baseline", "dd5"):
        for arch in (ARCHS[aname], _wired(ARCHS[aname])):
            packed = pack(net, arch)
            ir = packed.lower_ir()
            pl = placement_for(ir, arch, seed=0, refine="anneal")
            assert pl.refine == "anneal"
            want = analyze_placed_oracle(packed, pl)
            pir = apply_placement(ir, pl)
            assert analyze_ir(pir, arch) == want
            prog = build_suite_timing_program([pir])
            cp = float(prog.run(arch.delay_table()[None, :])[0, 0])
            assert cp == want["critical_path_ps"]
            if (arch.t_wire_hop1, arch.t_wire_hop2, arch.t_wire_long) \
                    == (0.0, 0.0, 0.0):
                # Fig-5/Table-III pins: zero wire == unplaced, bitwise
                assert want == analyze_oracle(packed)


def test_timing_driven_mode_weights_and_determinism():
    arch = ARCHS["dd5"]
    ir = _ir(arch=arch)
    crit = edge_criticality(ir, arch)
    assert crit.shape == (ir.fanin_sig.size,)
    assert (crit >= 0.0).all() and (crit <= 1.0).all()
    # some edge sits on the critical path (criticality 1 up to fp dust)
    assert crit.max() > 0.99
    w = criticality_weights(ir, arch, cache=False)
    src, _ = _routed_edges(ir)
    assert w.shape == (src.size,)
    assert (w >= 1.0).all()
    a = place_ir(ir, arch, seed=0, refine="anneal_timing")
    b = place_ir(ir, arch, seed=0, refine="anneal_timing")
    assert np.array_equal(a.lb_x, b.lb_x)
    assert np.array_equal(a.lb_y, b.lb_y)
    _legal(a, ir.n_lbs)
    assert a.refine == "anneal_timing"
    with pytest.raises(ValueError, match="refine mode"):
        place_ir(ir, arch, seed=0, refine="bogus")


def test_delay_signature_excludes_wire_tiers():
    """Criticality weighting may read the delay row but never the wire
    tiers — otherwise one placement could not serve a whole wire-delay
    family and the placement-reuse gate would silently die."""
    arch = ARCHS["dd5"]
    assert delay_signature(arch) == delay_signature(_wired(arch))
    slow_mux = make_arch("dd5_slowmux", bypass_inputs=2, addmux_fanin=10,
                         t_z_to_adder=400.0)
    assert delay_signature(arch) != delay_signature(slow_mux)


def test_refined_placement_cache_keys():
    """Analytic, uniform-annealed and timing-annealed placements are
    distinct registry entries; wire-delay rows share the annealed entry
    (the place-once-per-key reuse), while a different non-wire delay row
    re-anneals only in the timing-driven mode."""
    clear_caches()
    arch = ARCHS["dd5"]
    ir = _ir(arch=arch)
    base = placement_for(ir, arch, seed=0)
    ann = placement_for(ir, arch, seed=0, refine="anneal")
    tim = placement_for(ir, arch, seed=0, refine="anneal_timing")
    assert base.refine is None and ann.refine == "anneal"
    assert cache_stats()["placement"]["size"] == 3
    hits0 = PLACE_COUNTS["cache_hit"]
    assert placement_for(ir, arch, seed=0, refine="anneal") is ann
    # a wire-only delay variant is a cache hit for every refine mode
    wired = _wired(arch)
    assert placement_for(ir, wired, seed=0, refine="anneal") is ann
    assert placement_for(ir, wired, seed=0, refine="anneal_timing") is tim
    assert PLACE_COUNTS["cache_hit"] == hits0 + 3
    # a non-wire delay change re-keys only the timing-driven mode
    slow_mux = make_arch("dd5_slowmux", bypass_inputs=2, addmux_fanin=10,
                         t_z_to_adder=400.0)
    assert placement_for(ir, slow_mux, seed=0, refine="anneal") is ann
    assert placement_for(
        ir, slow_mux, seed=0, refine="anneal_timing") is not tim


def test_anneal_caches_in_registry_cleared_with_everything_else():
    """Regression mirroring the PR-6 placement-cache rule for the new
    annealer caches: refined placements and criticality weights must
    live in the plan registry — after ``clear_caches()`` a re-request
    re-solves (no stale object served) yet reproduces the same values
    (determinism)."""
    clear_caches()
    arch = ARCHS["dd5"]
    ir = _ir(arch=arch)
    n0 = ANNEAL_COUNTS["anneal"]
    c0 = ANNEAL_COUNTS["crit_solve"]
    a = placement_for(ir, arch, seed=0, refine="anneal_timing")
    assert ANNEAL_COUNTS["anneal"] == n0 + 1
    assert ANNEAL_COUNTS["crit_solve"] == c0 + 1
    assert cache_stats()["criticality"]["size"] == 1
    # warm: both caches hit, no new solves
    h0 = ANNEAL_COUNTS["crit_hit"]
    assert placement_for(ir, arch, seed=0, refine="anneal_timing") is a
    criticality_weights(ir, arch)
    assert ANNEAL_COUNTS["crit_hit"] == h0 + 1
    assert ANNEAL_COUNTS["anneal"] == n0 + 1
    clear_caches()
    assert cache_stats()["placement"]["size"] == 0
    assert cache_stats()["criticality"]["size"] == 0
    b = placement_for(ir, arch, seed=0, refine="anneal_timing")
    assert b is not a                       # re-solved, not stale
    assert ANNEAL_COUNTS["anneal"] == n0 + 2
    assert ANNEAL_COUNTS["crit_solve"] == c0 + 2
    assert np.array_equal(a.lb_x, b.lb_x)
    assert np.array_equal(a.lb_y, b.lb_y)


def test_jax_ensemble_no_worse_than_single_chain_seed():
    """The jax multi-chain ensemble keeps the best exact wirelength over
    [seed] + chains, so it can never lose to the analytic seed and its
    result is legal whatever the chains did."""
    arch = ARCHS["dd5"]
    ir = _ir(arch=arch)
    seed_pl = place_ir(ir, arch, seed=0)
    j = place_ir(ir, arch, seed=0, refine="anneal", backend="jax",
                 anneal_chains=2, anneal_steps=24)
    _legal(j, ir.n_lbs)
    assert j.wirelength(ir) <= seed_pl.wirelength(ir)
