"""IR-level tests: truth tables, structural hashing, sweep, evaluation."""
import random

from _hypothesis_shim import given, settings, st

from repro.core.netlist import (CONST0, CONST1, Netlist, TT_AND2, TT_MAJ3,
                                TT_XOR2, TT_XOR3, bus_to_ints, eval_netlist,
                                tt_compose, tt_eval, tt_from_fn, tt_reduce,
                                tt_var)


def test_tt_var_eval():
    for k in range(1, 5):
        for j in range(k):
            tt = tt_var(j, k)
            for m in range(1 << k):
                assert tt_eval(tt, m) == (m >> j) & 1


@given(st.integers(0, 255), st.integers(0, 7))
@settings(max_examples=50, deadline=None)
def test_tt_reduce_drops_duplicate_input(tt, seed):
    # build a 3-input tt where input 2 duplicates input 0
    ins, red = tt_reduce((5, 6, 5), tt)
    assert len(ins) <= 2
    for m in range(1 << 3):
        a, b, c = m & 1, (m >> 1) & 1, (m >> 2) & 1
        if a != c:
            continue  # unreachable assignment for duplicated input
        pos = {s: j for j, s in enumerate(ins)}
        mm = 0
        if 5 in pos and a:
            mm |= 1 << pos[5]
        if 6 in pos and b:
            mm |= 1 << pos[6]
        assert tt_eval(tt, m) == tt_eval(red, mm)


def test_tt_compose_matches_direct_eval():
    # outer = XOR3(p, q, r) with q replaced by AND2(u, v)
    outer_ins = (10, 11, 12)
    inner_ins = (20, 21)
    merged, tt = tt_compose(TT_XOR3, outer_ins, 1, TT_AND2, inner_ins)
    pos = {s: j for j, s in enumerate(merged)}
    for m in range(1 << len(merged)):
        val = {s: (m >> pos[s]) & 1 for s in merged}
        q = val[20] & val[21]
        exp = val[10] ^ q ^ val[12]
        assert tt_eval(tt, m) == exp


def test_structural_hash_luts():
    net = Netlist()
    a, b = net.add_pi_bus("a", 1)[0], net.add_pi_bus("b", 1)[0]
    o1 = net.add_lut((a, b), TT_AND2)
    o2 = net.add_lut((a, b), TT_AND2)
    assert o1 == o2
    assert net.n_luts == 1


def test_structural_hash_chains():
    net = Netlist()
    a = net.add_pi_bus("a", 4)
    b = net.add_pi_bus("b", 4)
    s1, _ = net.add_chain(list(a), list(b))
    s2, _ = net.add_chain(list(a), list(b))
    assert s1 == s2
    assert len(net.chains) == 1


def test_lut_constant_folding():
    net = Netlist()
    a = net.add_pi_bus("a", 1)[0]
    assert net.add_lut((a, CONST0), TT_AND2) == CONST0
    assert net.add_lut((a, CONST1), TT_AND2) == a
    assert net.add_lut((a, a), TT_XOR2) == CONST0


def test_sweep_removes_dead_logic():
    net = Netlist()
    a = net.add_pi_bus("a", 2)
    live = net.add_lut((a[0], a[1]), TT_AND2)
    net.add_lut((a[0], a[1]), TT_XOR2)  # dead
    net.set_po_bus("o", [live])
    swept = net.sweep()
    assert swept.n_luts == 1


def test_chain_evaluation_full_add():
    net = Netlist()
    a = net.add_pi_bus("a", 8)
    b = net.add_pi_bus("b", 8)
    sums, cout = net.add_chain(list(a), list(b), want_cout=True)
    net.set_po_bus("s", sums + [cout])
    rng = random.Random(0)
    NV = 32
    xs = [rng.getrandbits(8) for _ in range(NV)]
    ys = [rng.getrandbits(8) for _ in range(NV)]
    vals = {}
    for j in range(8):
        vals[a[j]] = sum(((xs[v] >> j) & 1) << v for v in range(NV))
        vals[b[j]] = sum(((ys[v] >> j) & 1) << v for v in range(NV))
    res = eval_netlist(net, vals, NV)
    got = bus_to_ints(res, sums + [cout], NV)
    for v in range(NV):
        assert got[v] == xs[v] + ys[v]


def test_topo_order_complete():
    net = Netlist()
    a = net.add_pi_bus("a", 4)
    x = net.add_lut((a[0], a[1]), TT_XOR2)
    y = net.add_lut((x, a[2]), TT_AND2)
    s, _ = net.add_chain([y, x], [a[3], a[0]])
    net.set_po_bus("o", s)
    order = net.topo_order()
    assert len(order) == 3
    assert order.index(("lut", 0)) < order.index(("lut", 1))
    assert order.index(("lut", 1)) < order.index(("chain", 0))
