"""Timing-model behaviour: Table II path relationships must show up."""
from repro.core.alm import BASELINE, DD5, DD6
from repro.core.circuits import kratos_gemm, sha_like, vtr_mixed
from repro.core.netlist import Netlist
from repro.core.packing import pack
from repro.core.timing import analyze, channel_utilization


def test_dd6_slower_than_dd5():
    net = kratos_gemm(m=6, n=6, width=6, sparsity=0.5)
    r5 = analyze(pack(net, DD5, seed=0))
    r6 = analyze(pack(net, DD6, seed=0))
    assert r6["critical_path_ps"] > r5["critical_path_ps"]


def test_z_path_speeds_up_adder_chains():
    """A pure adder circuit: DD5 feeds raw operands through Z (68.77 ps)
    instead of the LUT route (133.4 ps) -> lower critical path."""
    net = Netlist("adders")
    a = net.add_pi_bus("a", 32)
    b = net.add_pi_bus("b", 32)
    sums, _ = net.add_chain(list(a), list(b))
    net.set_po_bus("s", sums)
    r0 = analyze(pack(net, BASELINE, seed=0))
    r5 = analyze(pack(net, DD5, seed=0))
    assert r5["critical_path_ps"] < r0["critical_path_ps"]


def test_delay_roughly_flat_dd5():
    """Paper Fig. 6: average critical path is at the baseline level
    (within a few percent either way)."""
    for mk in (lambda: kratos_gemm(m=6, n=6, width=6, sparsity=0.5),
               lambda: vtr_mixed(logic_nodes=200, adders=3),
               lambda: sha_like(rounds=1)):
        net = mk()
        r0 = analyze(pack(net, BASELINE, seed=0))
        r5 = analyze(pack(net, DD5, seed=0))
        ratio = r5["critical_path_ps"] / r0["critical_path_ps"]
        assert 0.85 < ratio < 1.16, (net.name, ratio)


def test_area_model_tile_constants():
    assert abs(DD5.alm_area_mwta / BASELINE.alm_area_mwta - 1.0372) < 1e-6
    assert DD6.alm_area_mwta > DD5.alm_area_mwta


def test_channel_utilization_shifts_up_dd5():
    """Fig. 8: same logic in fewer LBs -> higher per-LB routing demand."""
    net = kratos_gemm(m=8, n=8, width=6, sparsity=0.5)
    u0 = channel_utilization(pack(net, BASELINE, seed=0))
    u5 = channel_utilization(pack(net, DD5, seed=0))
    assert sum(u5) / len(u5) > sum(u0) / len(u0)


def test_fmax_in_plausible_range():
    """Table III: suite Fmax averages sit around 70-160 MHz."""
    net = kratos_gemm(m=8, n=8, width=6, sparsity=0.5)
    r = analyze(pack(net, BASELINE, seed=0))
    assert 40 < r["fmax_mhz"] < 400
