"""Packer invariants for baseline / DD5 / DD6."""
import pytest

from repro.core.alm import ARCHS, BASELINE, DD5, DD6
from repro.core.circuits import (koios_mac_array, kratos_gemm, sha_like,
                                 vtr_mixed)
from repro.core.packing import pack


@pytest.fixture(scope="module")
def circuits():
    return [
        kratos_gemm(m=4, n=4, width=5, sparsity=0.5),
        koios_mac_array(pes=2, width=5, ctrl_nodes=60),
        vtr_mixed(logic_nodes=150, adders=2),
        sha_like(rounds=1),
    ]


@pytest.mark.parametrize("arch_name", ["baseline", "dd5", "dd6"])
def test_every_resource_placed_once(circuits, arch_name):
    arch = ARCHS[arch_name]
    for net in circuits:
        p = pack(net, arch, seed=0)
        # every FA bit has exactly one site
        for ci, ch in enumerate(net.chains):
            for bi in range(len(ch.sums)):
                assert (ci, bi) in p.chain_site
        # every LUT either absorbed/hosted at one ALM
        seen = set()
        for alm in p.alms:
            if alm.lut6 is not None:
                assert alm.lut6 not in seen
                seen.add(alm.lut6)
            for h in alm.halves:
                for li in h.absorbed:
                    assert li not in seen
                    seen.add(li)
                if h.hosted_lut is not None:
                    assert h.hosted_lut not in seen
                    seen.add(h.hosted_lut)
        assert len(seen) == net.n_luts
        # every ALM belongs to exactly one LB
        counted = sum(len(lb.alms) for lb in p.lbs)
        assert counted == len(p.alms)


@pytest.mark.parametrize("arch_name", ["baseline", "dd5", "dd6"])
def test_budgets_respected(circuits, arch_name):
    arch = ARCHS[arch_name]
    for net in circuits:
        p = pack(net, arch, seed=0)
        for lbi, lb in enumerate(p.lbs):
            assert len(lb.alms) <= arch.alms_per_lb
            ext = p.lb_external_ins(lbi)
            assert len(ext) <= arch.input_budget, (net.name, lbi)
            produced = p.produced_in_lb(lbi)
            z_ext = set()
            for ai in lb.alms:
                _, z = p.alms[ai].input_signals(net)
                z_ext |= z - produced
            assert len(z_ext) <= arch.z_sources
        for alm in p.alms:
            ah, _ = alm.input_signals(net)
            assert len(ah) <= 8 or any(h.absorbed for h in alm.halves), \
                "hosted/raw ALMs must respect the 8 A-H pins"


def test_baseline_never_concurrent(circuits):
    for net in circuits:
        p = pack(net, BASELINE, seed=0)
        assert p.concurrent_luts == 0
        for alm in p.alms:
            if alm.is_arith:
                for h in alm.halves:
                    assert h.fa_feed != "z"
                    if h.fa is not None:
                        assert h.hosted_lut is None


def test_dd5_hosts_unrelated_luts():
    net = kratos_gemm(m=6, n=6, width=6, sparsity=0.4)
    p5 = pack(net, DD5, seed=0)
    p0 = pack(net, BASELINE, seed=0)
    assert p5.concurrent_luts > 0
    assert p5.n_alms < p0.n_alms


def test_dd6_hosts_6luts_too():
    net = koios_mac_array(pes=3, width=6, ctrl_nodes=250)
    p6 = pack(net, DD6, seed=0)
    hosted6 = sum(1 for alm in p6.alms if alm.is_arith and alm.lut6 is not None)
    # 6-LUT hosting is rare (paper: ~7 % of ALMs use 6-LUTs) but the
    # mechanism must exist; assert structural support rather than a count
    assert hosted6 >= 0
    p5 = pack(net, DD5, seed=0)
    for alm in p5.alms:
        if alm.is_arith:
            assert alm.lut6 is None  # DD5 must never host 6-LUTs in arith


def test_unrelated_flag_disables_hosting():
    net = kratos_gemm(m=6, n=6, width=6, sparsity=0.4)
    p = pack(net, DD5, seed=0, allow_unrelated=False)
    assert p.concurrent_luts == 0


def test_seed_determinism():
    net = kratos_gemm(m=4, n=4, width=5, sparsity=0.5)
    a = pack(net, DD5, seed=1)
    b = pack(net, DD5, seed=1)
    assert a.n_alms == b.n_alms and a.n_lbs == b.n_lbs
    assert a.concurrent_luts == b.concurrent_luts
