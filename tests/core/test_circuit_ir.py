"""The unified CircuitIR substrate: one lowering serves eval, timing and
equivalence.

Property tests prove, from a SINGLE lowering per (circuit, structural
class): (a) fused evaluation bit-identical to the ``eval_netlist``
oracle, (b) timing bit-identical to ``analyze_oracle``, (c) identical
columns from fresh vs template-incremental lowering — across
baseline/DD5/DD6 plus cluster-geometry grid points.  Instrumentation
tests pin the no-duplicate-lowering property of ``sweep_suite`` and the
unified cache registry's invalidation semantics (the old
``clear_plan_caches`` left sweep templates live — regression)."""
import dataclasses

import numpy as np
import pytest

from repro.core import flow
from repro.core.alm import ARCHS, make_arch
from repro.core.circuit_ir import (CircuitIR, LOWER_COUNTS,
                                   lower_netlist_ir, lower_pack_ir,
                                   lower_pack_ir_incremental,
                                   read_lower_counts, reset_lower_counts)
from repro.core.circuits import kratos_gemm, sha_like
from repro.core.eval_jax import (clear_plan_caches, eval_netlist_jax,
                                 plan_from_ir, plan_netlist)
from repro.core.netlist import CONST0, CONST1, Netlist
from repro.core.packing import pack
from repro.core.plan import cache_stats, clear_caches
from repro.core.repack import pack_prefix, repack
from repro.core.sweep import sweep_suite
from repro.core.timing import analyze_oracle
from repro.core.timing_vec import analyze_ir

from _hypothesis_shim import given, settings, st
from test_flow import random_netlist

#: baseline/DD5/DD6 plus two cluster-geometry grid points — every
#: structural class the property tests lower through one prefix
ARCH_POINTS = [
    ARCHS["baseline"],
    ARCHS["dd5"],
    ARCHS["dd6"],
    make_arch("dd5_a8", bypass_inputs=2, alms_per_lb=8),
    make_arch("b0_i48", bypass_inputs=0, lb_inputs=48),
]


def _assert_same_ir(a: CircuitIR, b: CircuitIR):
    for f in dataclasses.fields(CircuitIR):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if f.name in ("lut_levels", "chain_levels"):
            assert len(va) == len(vb)
            for x, y in zip(va, vb):
                for g in dataclasses.fields(type(x)):
                    assert np.array_equal(getattr(x, g.name),
                                          getattr(y, g.name)), \
                        (f.name, g.name)
        elif isinstance(va, np.ndarray):
            assert np.array_equal(va, vb), f.name
        else:
            assert va == vb, f.name


@given(seed=st.integers(min_value=0, max_value=10_000))
@settings(max_examples=3, deadline=None)
def test_one_lowering_serves_eval_and_timing(seed):
    """The tentpole property: a single CircuitIR per (circuit,
    structural class) drives (a) fused evaluation equal to the python
    oracle, (b) timing bit-identical to ``analyze_oracle``, and (c)
    fresh and template-incremental lowering produce identical columns —
    across the canonical archs and cluster-geometry points."""
    net = random_netlist(seed)
    prefix = pack_prefix(net, seed=0)
    lanes = flow.random_lanes(net, 2, seed=seed)
    template = None
    for arch in ARCH_POINTS:
        packed = repack(prefix, arch)
        ir = lower_pack_ir(packed)
        # (c) incremental lowering parity, every column
        if template is not None:
            _assert_same_ir(ir, lower_pack_ir_incremental(packed, template))
        template = ir
        # (a) eval from the same IR object == python oracle
        vals = np.asarray(eval_netlist_jax(net, lanes, 2,
                                           plan=plan_from_ir(ir),
                                           use_pallas=False))
        assert flow.oracle_check(net, lanes, vals, 2), arch.name
        # (b) timing from the same IR object == python oracle, bit for bit
        want = analyze_oracle(packed)
        got = analyze_ir(ir, arch)
        assert got == want, arch.name


def test_functional_ir_serves_eval_of_const_fed_luts():
    """Constant operands are kept verbatim in the IR columns (the old
    packed lowering zeroed them): a LUT reading CONST1 and a chain with
    a CONST1 cin must evaluate exactly like the python oracle."""
    from repro.core.netlist import eval_netlist

    net = Netlist("constfed")
    a, b = net.add_pi_bus("in", 2)
    l1 = net.add_lut((a, CONST1, b), 0b10010110)      # parity with a 1
    l2 = net.add_lut((CONST0, l1), 0b0100)            # l1 & ~0
    sums, cout = net.add_chain([l1, l2], [b, CONST1], cin=CONST1,
                               want_cout=True)
    net.set_po_bus("s", sums)
    net.set_po_bus("c", [cout])
    lanes = flow.random_lanes(net, 2, seed=3)
    vals = np.asarray(eval_netlist_jax(net, lanes, 2, use_pallas=False))
    assert flow.oracle_check(net, lanes, vals, 2)
    # and the same functional IR's timing view under a pack stays exact
    for arch in (ARCHS["baseline"], ARCHS["dd5"]):
        packed = pack(net, arch, seed=0)
        assert analyze_ir(packed.lower_ir(), arch) == analyze_oracle(packed)


def test_sweep_suite_lowers_once_per_circuit_and_class():
    """Counter-instrumented no-duplicate-lowering property: a sweep over
    C circuits and K structural classes runs exactly C functional
    lowerings and C*K placement patches; a warm re-run (same caches)
    adds none."""
    clear_caches()
    reset_lower_counts()
    nets = [kratos_gemm(m=4, n=4, width=4, sparsity=0.5), random_netlist(6)]
    grid = [ARCHS["baseline"], ARCHS["dd5"],
            make_arch("g_a8", bypass_inputs=2, alms_per_lb=8)]
    packs: dict = {}
    programs: dict = {}
    prefixes: dict = {}
    res = sweep_suite(nets, grid, backend="numpy", packs=packs,
                      programs=programs, prefixes=prefixes)
    counts = read_lower_counts()
    assert counts["functional"] == len(nets)
    assert (counts["placement_full"] + counts["placement_incremental"]
            == len(nets) * res.n_classes)
    # the warm path re-lowers nothing at all
    sweep_suite(nets, grid, backend="numpy", packs=packs,
                programs=programs, prefixes=prefixes)
    assert read_lower_counts() == counts


def test_clear_caches_forces_relowering():
    """Regression (the cache-clearing bug): ``eval_jax.clear_plan_caches``
    used to leave the sweep's prefix-held IR templates live, so a
    "cleared" state could still patch from a stale template.  The unified
    registry drops templates too: after ``clear_caches()`` a sweep with
    warm prefixes must re-lower from scratch — and produce identical
    records."""
    clear_caches()
    nets = [random_netlist(11)]
    grid = [ARCHS["baseline"], ARCHS["dd5"]]
    prefixes: dict = {}
    res1 = sweep_suite(nets, grid, backend="numpy", prefixes=prefixes)
    assert len(prefixes) == 1
    prefix = next(iter(prefixes.values()))
    assert prefix.ir_template is not None     # template cached in registry
    reset_lower_counts()
    clear_plan_caches()                       # the old entry point — now
    assert prefix.ir_template is None         # reaches the templates too
    assert all(st["size"] == 0 for st in cache_stats().values())
    res2 = sweep_suite(nets, grid, backend="numpy", prefixes=prefixes)
    counts = read_lower_counts()
    assert counts["functional"] == 1          # forced full re-lowering
    assert counts["placement_full"] >= 1
    for g in range(len(nets)):
        for k in range(len(grid)):
            assert (res1.records[g][k]["critical_path_ps"]
                    == res2.records[g][k]["critical_path_ps"])


def test_ir_templates_are_seed_keyed():
    """A template lowered under one placement seed must never serve a
    prefix at another seed (the registry key carries the seed)."""
    clear_caches()
    net = kratos_gemm(m=4, n=4, width=4, sparsity=0.5)
    p0 = pack_prefix(net, seed=0)
    p0.ir_template = repack(p0, ARCHS["dd5"]).lower_ir()
    assert p0.ir_template is not None
    p1 = pack_prefix(net, seed=1)
    assert p1.ir_template is None


def test_plan_cache_cleared_by_unified_registry():
    """``plan_netlist`` results live in the registry: identical content
    hits, and ``clear_caches()`` forces a rebuild."""
    net = kratos_gemm(m=3, n=3, width=4, sparsity=0.3)
    p1 = plan_netlist(net)
    assert plan_netlist(net) is p1
    clear_caches()
    assert plan_netlist(net) is not p1


def test_functional_ir_is_content_cached_and_shared():
    """One functional IR per content digest serves both eval planning and
    packed lowering — the netlist-shaped arrays of a packed IR are the
    functional IR's arrays (no copy, no re-levelization)."""
    clear_caches()
    reset_lower_counts()
    net = sha_like(rounds=1)
    func = lower_netlist_ir(net)
    assert lower_netlist_ir(net) is func
    plan_netlist(net)
    packed_ir = pack(net, ARCHS["dd5"], seed=0).lower_ir()
    assert read_lower_counts()["functional"] == 1
    assert packed_ir.fanin_sig is func.fanin_sig
    assert packed_ir.po_sig is func.po_sig
    for ll_p, ll_f in zip(packed_ir.lut_levels, func.lut_levels):
        assert ll_p.ins is ll_f.ins and ll_p.tt_lo is ll_f.tt_lo


@pytest.mark.parametrize("arch_name", ["baseline", "dd5"])
def test_vector_cone_closure_matches_python_ints(arch_name):
    """The vectorized residue-cone closure (cones extracted into
    standalone netlists, evaluated through the unified evaluator over
    all 2^W assignments) agrees with the python-int enumeration entry
    for entry, and still catches corruption."""
    import random

    from repro.core.equiv import exhaustive_residue_report, reelaborate

    rng = random.Random(1)
    net = Netlist("wide")
    ins = net.add_pi_bus("in", 14)
    a_ops, b_ops = [], []
    for i in range(6):
        la = net.add_lut(tuple(rng.sample(ins, 4)), rng.getrandbits(16))
        lb = net.add_lut(tuple(rng.sample(ins, 4)), rng.getrandbits(16))
        a_ops.append(la)
        b_ops.append(lb)
        net.set_po_bus(f"keep{i}", [la, lb])   # fanout > 1: no absorption
    sums, cout = net.add_chain(a_ops, b_ops, want_cout=True)
    net.set_po_bus("s", sums)
    net.set_po_bus("c", [cout])
    re_elab = reelaborate(pack(net, ARCHS[arch_name], seed=0))
    residue = [("lut", i) for i in range(net.n_luts)] \
        + [("chain", i) for i in range(len(net.chains))]
    rv = exhaustive_residue_report(net, re_elab, residue,
                                   vector_min_support=1)
    rp = exhaustive_residue_report(net, re_elab, residue,
                                   vector_min_support=99)
    assert rv["vector_cones"] > 0
    assert rv["proven_cones"] == rp["proven_cones"] == len(residue)
    assert rv["unclosed"] == rp["unclosed"]
    assert rv["mismatches"] == rp["mismatches"]
    # corruption must fail through the vector path too
    re_elab.phys.lut_tt[0] ^= 1
    bad = exhaustive_residue_report(net, re_elab, residue,
                                    vector_min_support=1)
    assert bad["mismatches"]


def test_cone_extraction_pi_leaf_raises_keyerror():
    """Regression: a cone leaf that is a PI outside the support must
    raise KeyError (the unclosed-cone signal callers catch and fall back
    on), not fall through the driver dispatch into the chain branch and
    crash with IndexError."""
    from repro.core.equiv import _extract_cone_netlist

    net = Netlist("pileaf")
    a, b, c = net.add_pi_bus("in", 3)
    o = net.add_lut((a, b, c), 0b10010110)
    net.set_po_bus("po", [o])
    with pytest.raises(KeyError):
        _extract_cone_netlist(net, [o], [a, b])   # c is outside the cut


def test_eval_mode_cost_model_and_forced_modes():
    """The warm-path grouping heuristic: the model record carries both
    sides' costs and a pick; forced grouped / per-circuit evaluation are
    bit-identical to each other and to the oracle; auto stats record the
    decision."""
    nets = [random_netlist(s) for s in (3, 7)]
    lanes = [flow.random_lanes(n, 1, seed=i) for i, n in enumerate(nets)]
    model = flow.eval_mode_cost_model(nets)
    assert model["pick"] in ("grouped", "per_circuit")
    assert model["cost_grouped"] >= model["padded_rows_grouped"]
    assert model["cost_per_circuit"] >= model["padded_rows_per_circuit"]
    outs_g, stats_g = flow.evaluate_suite(nets, lanes, 1, mode="grouped",
                                          use_pallas=False)
    outs_p, stats_p = flow.evaluate_suite(nets, lanes, 1,
                                          mode="per_circuit",
                                          use_pallas=False)
    assert stats_g["mode"] == "grouped" and stats_p["mode"] == "per_circuit"
    for net, ln, g, p in zip(nets, lanes, outs_g, outs_p):
        assert np.array_equal(g, p), net.name
        assert flow.oracle_check(net, ln, g, 1)
    outs_a, stats_a = flow.evaluate_suite(nets, lanes, 1, mode="auto",
                                          use_pallas=False)
    assert stats_a["mode"] == stats_a["cost_model"]["pick"]
    for g, a in zip(outs_g, outs_a):
        assert np.array_equal(g, a)
    with pytest.raises(ValueError):
        flow.evaluate_suite(nets, lanes, 1, mode="bogus")


def test_lower_counts_are_plain_ints():
    reset_lower_counts()
    assert read_lower_counts() == {k: 0 for k in LOWER_COUNTS}
