"""JAX evaluators (fused single-jit engine + seed per-level dispatcher)
vs the Python oracle."""
import random

import numpy as np
import pytest

from repro.core.circuits import koios_mac_array, kratos_gemm, sha_like
from repro.core.eval_jax import (eval_netlist_jax, eval_netlist_jax_levels,
                                 eval_netlists_batched_jax, plan_netlist)
from repro.core.netlist import bus_to_ints, eval_netlist


@pytest.mark.parametrize("mk", [
    lambda: kratos_gemm(m=4, n=4, width=5, sparsity=0.4),
    lambda: koios_mac_array(pes=2, width=4, ctrl_nodes=40),
    lambda: sha_like(rounds=1),
])
@pytest.mark.parametrize("use_pallas", [True, False])
def test_eval_jax_matches_python(mk, use_pallas):
    net = mk()
    rng = random.Random(42)
    NV = 32  # one uint32 lane word
    pi_vals = {s: rng.getrandbits(NV) for s in net.pis}
    ref = eval_netlist(net, pi_vals, NV)
    lanes = {s: np.array([v], dtype=np.uint32) for s, v in pi_vals.items()}
    got = np.asarray(eval_netlist_jax(net, lanes, 1, use_pallas=use_pallas))
    for bus in net.pos.values():
        for s in bus:
            assert int(got[s, 0]) == ref[s] & 0xFFFFFFFF, s


def test_eval_jax_multiword_lanes():
    net = kratos_gemm(m=3, n=3, width=4, sparsity=0.3)
    rng = random.Random(1)
    NW = 4  # 128 test vectors
    lanes = {s: np.array([rng.getrandbits(32) for _ in range(NW)],
                         dtype=np.uint32) for s in net.pis}
    got = np.asarray(eval_netlist_jax(net, lanes, NW))
    # cross-check one lane word against the oracle
    pi_vals = {s: int(lanes[s][2]) for s in net.pis}
    ref = eval_netlist(net, pi_vals, 32)
    for bus in net.pos.values():
        for s in bus:
            assert int(got[s, 2]) == ref[s] & 0xFFFFFFFF


def test_fused_matches_levels_dispatcher():
    """The fused single-jit engine and the seed per-level dispatcher are
    the same function of the same netlist."""
    net = koios_mac_array(pes=2, width=4, ctrl_nodes=40)
    rng = random.Random(5)
    NW = 2
    lanes = {s: np.array([rng.getrandbits(32) for _ in range(NW)],
                         dtype=np.uint32) for s in net.pis}
    fused = np.asarray(eval_netlist_jax(net, lanes, NW))
    levels = np.asarray(eval_netlist_jax_levels(net, lanes, NW))
    assert np.array_equal(fused, levels)


def test_precompiled_plan_reuse():
    net = kratos_gemm(m=3, n=3, width=4, sparsity=0.3)
    plan = plan_netlist(net)
    rng = random.Random(9)
    lanes = {s: np.array([rng.getrandbits(32)], dtype=np.uint32)
             for s in net.pis}
    a = np.asarray(eval_netlist_jax(net, lanes, 1))
    b = np.asarray(eval_netlist_jax(net, lanes, 1, plan=plan))
    assert np.array_equal(a, b)


def test_batched_multi_circuit_eval():
    """Different circuits, one vmapped jit: each must match its own
    single-circuit evaluation."""
    nets = [kratos_gemm(m=3, n=3, width=4, sparsity=0.3),
            sha_like(rounds=1),
            koios_mac_array(pes=2, width=4, ctrl_nodes=40)]
    rng = random.Random(3)
    NW = 2
    lanes_list = [{s: np.array([rng.getrandbits(32) for _ in range(NW)],
                               dtype=np.uint32) for s in net.pis}
                  for net in nets]
    outs = eval_netlists_batched_jax(nets, lanes_list, NW)
    for net, lanes, got in zip(nets, lanes_list, outs):
        single = np.asarray(eval_netlist_jax(net, lanes, NW))
        for bus in net.pos.values():
            for s in bus:
                assert np.array_equal(got[s], single[s]), (net.name, s)


def test_plan_is_width_bucketed():
    """Plans split the level sequence into <= 3 contiguous width buckets
    whose padded volume never exceeds the single worst-case envelope."""
    net = koios_mac_array(pes=2, width=4, ctrl_nodes=40)
    plan = plan_netlist(net)
    assert 1 <= len(plan.buckets) <= 3
    assert sum(bk.n_levels for bk in plan.buckets) == plan.n_levels
    L, M, C, B = plan.envelope
    assert plan.padded_lut_rows + plan.padded_chain_bits \
        <= L * M + L * C * B
    # every real node is represented exactly once
    assert plan.real_luts == net.n_luts
    assert plan.real_chain_bits == net.n_adders


def test_plan_cache_keyed_by_content():
    """Identical structure -> same cached plan object; a structural edit
    (new digest) -> a fresh plan."""
    net = kratos_gemm(m=3, n=3, width=4, sparsity=0.3)
    p1 = plan_netlist(net)
    p2 = plan_netlist(net)
    assert p1 is p2
    net2 = kratos_gemm(m=3, n=3, width=4, sparsity=0.3)
    assert plan_netlist(net2) is p1  # same content, same key
    net2.lut_tt[0] ^= 1
    assert plan_netlist(net2) is not p1


def test_grouped_eval_respects_max_groups_and_matches_single():
    nets = [kratos_gemm(m=3, n=3, width=4, sparsity=0.3),
            sha_like(rounds=1),
            koios_mac_array(pes=2, width=4, ctrl_nodes=40),
            kratos_gemm(m=4, n=4, width=4, sparsity=0.5, seed=7)]
    rng = random.Random(11)
    NW = 1
    lanes_list = [{s: np.array([rng.getrandbits(32)], dtype=np.uint32)
                   for s in net.pis} for net in nets]
    outs, stats = eval_netlists_batched_jax(nets, lanes_list, NW,
                                            max_groups=2, return_stats=True)
    assert stats["n_groups"] <= 2
    names = sorted(m for g in stats["groups"] for m in g["members"])
    assert names == sorted(n.name for n in nets)
    for net, lanes, got in zip(nets, lanes_list, outs):
        single = np.asarray(eval_netlist_jax(net, lanes, NW))
        for bus in net.pos.values():
            for s in bus:
                assert np.array_equal(got[s], single[s]), (net.name, s)
