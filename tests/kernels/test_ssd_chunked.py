"""Chunked-dual SSD (jnp) vs sequential reference (§Perf cell 3)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels.ref import ssd_scan_chunked_ref, ssd_scan_ref


@pytest.mark.parametrize("bb,L,H,P,N,chunk", [
    (1, 128, 2, 8, 4, 32),
    (2, 256, 3, 16, 8, 64),
    (1, 512, 2, 8, 16, 128),
    (1, 96, 2, 8, 4, 50),   # non-divisor chunk: falls back to sequential
])
def test_chunked_matches_sequential(bb, L, H, P, N, chunk):
    r = np.random.default_rng(L + chunk)
    x = jnp.asarray(r.standard_normal((bb, L, H, P)).astype(np.float32) * 0.4)
    dt = jnp.asarray((0.01 + 0.04 * r.random((bb, L, H))).astype(np.float32))
    A = jnp.asarray((-0.5 - r.random(H)).astype(np.float32))
    B = jnp.asarray(r.standard_normal((bb, L, N)).astype(np.float32) * 0.5)
    C = jnp.asarray(r.standard_normal((bb, L, N)).astype(np.float32) * 0.5)
    a = ssd_scan_ref(x, dt, A, B, C)
    b = ssd_scan_chunked_ref(x, dt, A, B, C, chunk=chunk)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=3e-4, atol=3e-4)


def test_model_uses_chunked_path():
    import dataclasses

    import jax

    from repro.configs.base import get_config
    from repro.models.lm import forward, init_params

    cfg = dataclasses.replace(get_config("mamba2-2.7b").smoke(), ssd_chunk=16)
    params = init_params(jax.random.key(0), cfg)
    toks = jnp.asarray(np.arange(2 * 64).reshape(2, 64) % (cfg.vocab - 1) + 1)
    lo_c, _ = forward(cfg, params, toks)
    cfg0 = dataclasses.replace(cfg, ssd_chunk=0)
    lo_s, _ = forward(cfg0, params, toks)
    np.testing.assert_allclose(np.asarray(lo_c), np.asarray(lo_s),
                               rtol=2e-3, atol=2e-3)
