"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def rng(seed=0):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# popcount_matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,n,words", [(4, 4, 1), (16, 8, 2), (130, 70, 3),
                                       (256, 128, 4)])
@pytest.mark.parametrize("mode", ["and", "xnor"])
def test_popcount_matmul(m, n, words, mode):
    r = rng(m * 7 + n)
    x = r.integers(0, 2**32, size=(m, words), dtype=np.uint32)
    w = r.integers(0, 2**32, size=(n, words), dtype=np.uint32)
    kb = words * 32
    got = ops.popcount_matmul(jnp.asarray(x), jnp.asarray(w), mode=mode,
                              k_bits=kb)
    want = ref.popcount_matmul_ref(jnp.asarray(x), jnp.asarray(w), mode=mode,
                                   k_bits=kb)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_popcount_matmul_matches_integer_dot():
    r = rng(3)
    K = 64
    xb = r.integers(0, 2, size=(5, K)).astype(np.uint8)
    wb = r.integers(0, 2, size=(7, K)).astype(np.uint8)

    def pack(bits):
        out = np.zeros((bits.shape[0], K // 32), dtype=np.uint32)
        for k in range(K):
            out[:, k // 32] |= (bits[:, k].astype(np.uint32)) << (k % 32)
        return out

    got = ops.popcount_matmul(jnp.asarray(pack(xb)), jnp.asarray(pack(wb)),
                              mode="and")
    want = xb.astype(np.int32) @ wb.T.astype(np.int32)
    np.testing.assert_array_equal(np.asarray(got), want)


# ---------------------------------------------------------------------------
# lut_eval
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,nlanes", [(8, 2, 4), (64, 3, 16), (300, 4, 8),
                                        (1000, 5, 2)])
def test_lut_eval(m, k, nlanes):
    r = rng(m + k)
    ins = r.integers(0, 2**32, size=(m, k, nlanes), dtype=np.uint32)
    tts = r.integers(0, 2**(2**k), size=(m,),
                     dtype=np.uint64).astype(np.uint32) \
        if k < 5 else r.integers(0, 2**32, size=(m,), dtype=np.uint32)
    got = ops.lut_eval(jnp.asarray(ins), jnp.asarray(tts))
    want = ref.lut_eval_ref(jnp.asarray(ins), jnp.asarray(tts))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_lut_eval_known_functions():
    # AND2 / XOR2 bit-parallel
    ins = np.zeros((2, 2, 1), dtype=np.uint32)
    ins[0, 0, 0] = 0b1100
    ins[0, 1, 0] = 0b1010
    ins[1, 0, 0] = 0b1100
    ins[1, 1, 0] = 0b1010
    tts = np.array([0b1000, 0b0110], dtype=np.uint32)  # AND2, XOR2
    got = np.asarray(ops.lut_eval(jnp.asarray(ins), jnp.asarray(tts)))
    assert got[0, 0] == 0b1000
    assert got[1, 0] == 0b0110


@pytest.mark.parametrize("m,nlanes", [(8, 4), (300, 8), (513, 2)])
def test_lut_eval6_fused_layout(m, nlanes):
    r = rng(m * 7)
    ins = r.integers(0, 2**32, size=(m, 6, nlanes), dtype=np.uint32)
    tt_lo = r.integers(0, 2**32, size=(m,), dtype=np.uint32)
    tt_hi = r.integers(0, 2**32, size=(m,), dtype=np.uint32)
    got = ops.lut_eval6(jnp.asarray(ins), jnp.asarray(tt_lo),
                        jnp.asarray(tt_hi))
    want = ref.lut_eval6_ref(jnp.asarray(ins), jnp.asarray(tt_lo),
                             jnp.asarray(tt_hi))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_lut_eval6_shannon_select():
    # pin5 selects between the lo/hi table words: table = XOR2 in lo,
    # AND2 in hi, pin5 toggling per lane bit
    ins = np.zeros((1, 6, 1), dtype=np.uint32)
    ins[0, 0, 0] = 0b1100
    ins[0, 1, 0] = 0b1010
    ins[0, 5, 0] = 0b0011  # vector bits 0-1 read hi, bits 2-3 read lo
    lo = np.array([0x66666666], dtype=np.uint32)  # XOR2 replicated
    hi = np.array([0x88888888], dtype=np.uint32)  # AND2 replicated
    got = np.asarray(ops.lut_eval6(jnp.asarray(ins), jnp.asarray(lo),
                                   jnp.asarray(hi)))
    # bits 2,3 (lo): XOR2(1,0)=1, XOR2(1,1)=0; bits 0,1 (hi): AND2=0
    assert got[0, 0] == 0b0100


# ---------------------------------------------------------------------------
# bitplane_matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n,b", [(4, 8, 4, 2), (32, 64, 16, 4),
                                     (128, 256, 128, 3), (65, 130, 70, 8)])
def test_bitplane_matmul(m, k, n, b):
    r = rng(m + k + n + b)
    x = r.standard_normal((m, k)).astype(np.float32)
    planes = r.integers(0, 2, size=(b, k, n)).astype(np.float32)
    scale = (r.standard_normal(n).astype(np.float32)) * 0.1
    got = ops.bitplane_matmul(jnp.asarray(x), jnp.asarray(planes),
                              jnp.asarray(scale))
    want = ref.bitplane_matmul_ref(jnp.asarray(x), jnp.asarray(planes),
                                   jnp.asarray(scale))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_bitplane_matmul_matches_int_quantized():
    """The kernel must equal a real two's-complement quantized matmul."""
    r = rng(5)
    m, k, n, b = 8, 16, 8, 4
    w_int = r.integers(-(2 ** (b - 1)), 2 ** (b - 1), size=(k, n))
    planes = np.zeros((b, k, n), dtype=np.float32)
    w_uint = (w_int % (2 ** b)).astype(np.uint32)
    for bit in range(b):
        planes[bit] = (w_uint >> bit) & 1
    x = r.standard_normal((m, k)).astype(np.float32)
    scale = np.full(n, 0.5, dtype=np.float32)
    got = ops.bitplane_matmul(jnp.asarray(x), jnp.asarray(planes),
                              jnp.asarray(scale))
    want = (x @ w_int.astype(np.float32)) * 0.5
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


CASES = [
    # B, Hq, Hkv, S, T, D, causal, window, softcap
    (1, 2, 2, 64, 64, 32, True, None, None),
    (2, 4, 2, 128, 128, 64, True, None, None),       # GQA
    (1, 8, 1, 64, 64, 32, True, None, None),         # MQA
    (1, 2, 2, 64, 64, 32, True, 32, None),           # sliding window
    (1, 2, 2, 64, 64, 32, True, None, 30.0),         # softcap (gemma2)
    (1, 2, 1, 16, 128, 32, True, None, None),        # decode: S < T
    (1, 2, 2, 64, 64, 32, False, None, None),        # bidirectional
]


@pytest.mark.parametrize("case", CASES)
def test_flash_attention(case):
    B, Hq, Hkv, S, T, D, causal, window, softcap = case
    r = rng(sum(case[:6]))
    q = r.standard_normal((B, Hq, S, D)).astype(np.float32)
    k = r.standard_normal((B, Hkv, T, D)).astype(np.float32)
    v = r.standard_normal((B, Hkv, T, D)).astype(np.float32)
    got = ops.flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                              causal=causal, window=window, softcap=softcap)
    want = ref.flash_attention_ref(jnp.asarray(q), jnp.asarray(k),
                                   jnp.asarray(v), causal=causal,
                                   window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    r = rng(9)
    q = r.standard_normal((1, 2, 64, 32)).astype(np.float32)
    k = r.standard_normal((1, 2, 64, 32)).astype(np.float32)
    v = r.standard_normal((1, 2, 64, 32)).astype(np.float32)
    got = ops.flash_attention(jnp.asarray(q, dtype=jnp.bfloat16),
                              jnp.asarray(k, dtype=jnp.bfloat16),
                              jnp.asarray(v, dtype=jnp.bfloat16))
    want = ref.flash_attention_ref(jnp.asarray(q), jnp.asarray(k),
                                   jnp.asarray(v))
    np.testing.assert_allclose(np.asarray(got, dtype=np.float32),
                               np.asarray(want), rtol=2e-2, atol=2e-2)


def test_flash_attention_grad_matches_ref():
    r = rng(11)
    q = jnp.asarray(r.standard_normal((1, 2, 32, 16)).astype(np.float32))
    k = jnp.asarray(r.standard_normal((1, 2, 32, 16)).astype(np.float32))
    v = jnp.asarray(r.standard_normal((1, 2, 32, 16)).astype(np.float32))

    def f_pallas(q, k, v):
        return ops.flash_attention(q, k, v).sum()

    def f_ref(q, k, v):
        return ref.flash_attention_ref(q, k, v).sum()

    g1 = jax.grad(f_pallas, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bb,L,H,P,N,chunk_note", [
    (1, 128, 2, 16, 8, "single chunk"),
    (2, 256, 2, 32, 16, "two chunks"),
    (1, 512, 4, 16, 32, "four chunks"),
])
def test_ssd_scan(bb, L, H, P, N, chunk_note):
    r = rng(L + H + P)
    x = r.standard_normal((bb, L, H, P)).astype(np.float32) * 0.5
    dt = (0.001 + 0.05 * r.random((bb, L, H))).astype(np.float32)
    A = (-0.5 - r.random(H)).astype(np.float32)
    B = r.standard_normal((bb, L, N)).astype(np.float32) * 0.5
    C = r.standard_normal((bb, L, N)).astype(np.float32) * 0.5
    got = ops.ssd_scan(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A),
                       jnp.asarray(B), jnp.asarray(C))
    want = ref.ssd_scan_ref(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A),
                            jnp.asarray(B), jnp.asarray(C))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


def test_ssd_scan_state_continuity():
    """Splitting a sequence into chunks must match one long chunk —
    the carried VMEM state is doing its job."""
    r = rng(21)
    x = r.standard_normal((1, 256, 1, 8)).astype(np.float32) * 0.3
    dt = (0.01 + 0.02 * r.random((1, 256, 1))).astype(np.float32)
    A = np.array([-1.0], dtype=np.float32)
    B = r.standard_normal((1, 256, 4)).astype(np.float32)
    C = r.standard_normal((1, 256, 4)).astype(np.float32)
    got = ops.ssd_scan(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A),
                       jnp.asarray(B), jnp.asarray(C))       # CHUNK=128 → 2
    want = ref.ssd_scan_ref(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A),
                            jnp.asarray(B), jnp.asarray(C))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)
