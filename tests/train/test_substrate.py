"""Substrate tests: optimizer math, checkpoint roundtrip + crash recovery,
fault-tolerant loop, data determinism, quantization, serve equivalence."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.configs.base import get_config
from repro.data.pipeline import batch_for_step
from repro.models.lm import forward, init_params
from repro.quant.bitplane import (bitplane_linear, dequantize,
                                  quantize_bitplanes)
from repro.serve.decode import decode_step, prefill
from repro.serve.kvcache import init_cache
from repro.train.loop import FitConfig, fit
from repro.train.optimizer import (OptConfig, adamw_init, adamw_update,
                                   adafactor_init, adafactor_update,
                                   global_norm)
from repro.train.step import TrainConfig

pytestmark = pytest.mark.slow  # model-substrate tier: minutes of CPU


def test_adamw_matches_numpy_reference():
    cfg = OptConfig(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                    clip_norm=1e9, warmup_steps=0, decay_steps=10**9,
                    min_lr_frac=1.0)
    p = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]], jnp.float32)}
    g = {"w": jnp.asarray([[0.1, 0.2], [-0.3, 0.4]], jnp.float32)}
    st = adamw_init(p)
    newp, st, _ = adamw_update(cfg, g, st, p)
    # numpy reference
    m = 0.1 * np.asarray(g["w"])
    v = 0.01 * np.asarray(g["w"]) ** 2
    mh = m / (1 - 0.9)
    vh = v / (1 - 0.99)
    ref = np.asarray(p["w"]) - 1e-2 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(newp["w"]), ref, rtol=1e-5)


def test_adafactor_reduces_loss_direction():
    cfg = OptConfig(name="adafactor", lr=1e-2, warmup_steps=0,
                    decay_steps=10**9, min_lr_frac=1.0, weight_decay=0.0)
    p = {"w": jnp.ones((8, 8), jnp.float32)}
    g = {"w": jnp.ones((8, 8), jnp.float32)}
    st = adafactor_init(p)
    newp, st, _ = adafactor_update(cfg, g, st, p)
    assert float(newp["w"].mean()) < 1.0  # moved against gradient
    assert st["v"]["w"]["vr"].shape == (8,)
    assert st["v"]["w"]["vc"].shape == (8,)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    ckpt.save(str(tmp_path), 7, tree)
    restored, step = ckpt.restore(str(tmp_path), tree)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_keeps_last_k(tmp_path):
    tree = {"a": jnp.zeros((2,))}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, tree, keep_last=2)
    steps = sorted(os.listdir(tmp_path))
    assert steps == ["step_00000004", "step_00000005"]


def test_checkpoint_atomic_no_partial(tmp_path):
    tree = {"a": jnp.zeros((2,))}
    ckpt.save(str(tmp_path), 1, tree)
    # a stale tmp dir must not confuse restore
    os.makedirs(tmp_path / ".tmp_ckpt_dead", exist_ok=True)
    restored, step = ckpt.restore(str(tmp_path), tree)
    assert step == 1


def test_fit_resumes_from_checkpoint(tmp_path):
    cfg = get_config("qwen1.5-0.5b").smoke()
    params = init_params(jax.random.key(0), cfg)
    fitc = FitConfig(steps=6, ckpt_every=3, ckpt_dir=str(tmp_path),
                     seq_len=32, global_batch=2)
    r1 = fit(cfg, params, fitc)
    assert ckpt.latest_step(str(tmp_path)) == 6
    # "crash" and resume: a fresh fit with more steps starts from step 6
    fitc2 = FitConfig(steps=8, ckpt_every=4, ckpt_dir=str(tmp_path),
                      seq_len=32, global_batch=2)
    params2 = init_params(jax.random.key(0), cfg)
    r2 = fit(cfg, params2, fitc2)
    assert r2["final_step"] == 8
    assert len(r2["losses"]) == 2  # only steps 6,7 ran


def test_data_pipeline_deterministic_and_sharded():
    cfg = get_config("tinyllama-1.1b").smoke()
    a = batch_for_step(cfg, 64, 8, step=3, seed=1)
    b = batch_for_step(cfg, 64, 8, step=3, seed=1)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = batch_for_step(cfg, 64, 8, step=4, seed=1)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # shards partition the global batch deterministically
    s0 = batch_for_step(cfg, 64, 8, step=3, seed=1, shard=0, n_shards=2)
    s1 = batch_for_step(cfg, 64, 8, step=3, seed=1, shard=1, n_shards=2)
    assert s0["tokens"].shape == (4, 64)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_bitplane_quantization_roundtrip():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((64, 32)).astype(np.float32))
    planes, scale = quantize_bitplanes(w, bits=8)
    w2 = dequantize(planes, scale)
    err = float(jnp.abs(w - w2).max() / jnp.abs(w).max())
    assert err < 0.02, err
    x = jnp.asarray(rng.standard_normal((4, 64)).astype(np.float32))
    y = bitplane_linear(x, planes, scale)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w2),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "gemma2-2b",
                                  "deepseek-moe-16b", "mamba2-2.7b",
                                  "hymba-1.5b", "whisper-small"])
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch).smoke()
    params = init_params(jax.random.key(2), cfg)
    B, S = 2, 12
    toks = jnp.asarray((np.arange(B * S).reshape(B, S) % (cfg.vocab - 1)) + 1)
    enc = None
    kw = {}
    if cfg.family == "encdec":
        enc = jnp.ones((B, cfg.encoder_seq, cfg.d_model), jnp.float32) * 0.01
        kw["encoder_feats"] = enc
    logits_full, _ = forward(cfg, params, toks, **kw)
    cache = init_cache(cfg, B, S + 2,
                       encoder_len=(cfg.encoder_seq if enc is not None
                                    else None))
    _, cache = prefill(cfg, params, cache, toks[:, :S - 1],
                       encoder_feats=enc)
    lgd, _ = decode_step(cfg, params, cache, toks[:, S - 1:S], S - 1)
    err = float(jnp.abs(lgd[:, 0] - logits_full[:, S - 1]).max())
    assert err < 5e-3, err
