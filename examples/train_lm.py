"""End-to-end driver: train a ~100M-param TinyLlama-family model for a few
hundred steps on the synthetic pipeline, with checkpointing and restart.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]

This exercises the full production path: config -> init -> sharded train
step (remat, grad-accum) -> fault-tolerant loop -> checkpoint -> resume.
On CPU it uses a width-reduced ~15M variant by default; pass --full for the
real 100M config if you have the cores.
"""
import argparse
import dataclasses
import shutil
import tempfile

import jax

from repro.configs.base import ModelConfig, register
from repro.launch import train as train_launch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true",
                    help="train the real ~100M config (slow on CPU)")
    args = ap.parse_args()

    if args.full:
        cfg = ModelConfig(
            name="llama-100m", family="dense", n_layers=12, d_model=768,
            n_heads=12, n_kv_heads=4, head_dim=64, d_ff=2048, vocab=32000,
            act="swiglu", param_dtype="float32", compute_dtype="float32")
    else:
        cfg = ModelConfig(
            name="llama-100m", family="dense", n_layers=4, d_model=256,
            n_heads=8, n_kv_heads=4, head_dim=32, d_ff=688, vocab=2048,
            act="swiglu", param_dtype="float32", compute_dtype="float32",
            remat=False, loss_chunk=128)
    register(cfg)

    ckpt_dir = tempfile.mkdtemp(prefix="repro_train_lm_")
    try:
        result = train_launch.main([
            "--arch", cfg.name, "--steps", str(args.steps),
            "--seq-len", "128", "--batch", "8", "--lr", "1e-3",
            "--ckpt-dir", ckpt_dir,
        ])
        first, last = result["losses"][0], result["losses"][-1]
        print(f"loss {first:.3f} -> {last:.3f} over {args.steps} steps")
        assert last < first, "training did not reduce loss"
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
