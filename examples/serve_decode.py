"""Serve a small model with batched requests: prefill + greedy decode on a
KV cache, across three architecture families (dense GQA, SSM, hybrid).

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
from repro.launch import serve as serve_launch


def main():
    for arch in ("qwen1.5-0.5b", "mamba2-2.7b", "hymba-1.5b"):
        print(f"=== {arch} (smoke config) ===")
        serve_launch.main(["--arch", arch, "--smoke", "--batch", "4",
                           "--prompt-len", "24", "--max-new", "12"])


if __name__ == "__main__":
    main()
