"""Double-Duty on TPU: serve a model whose big linears run through the
bitplane (unrolled constant-weight) kernel — the paper's §IV decomposition
executed as MXU plane-matmuls + VPU shift-add (see DESIGN.md §3).

Compares logits between the fp32 path and the b-bit bitplane path and
reports the plane sparsity that the paper's row-skip optimization would
exploit.

Run:  PYTHONPATH=src python examples/quantized_serve.py [--bits 6]
"""
import argparse

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models.lm import forward, init_params
from repro.quant.bitplane import (bitplane_linear, plane_sparsity,
                                  quantize_bitplanes)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bits", type=int, default=6)
    args = ap.parse_args()

    cfg = get_config("kratos-dd").smoke()
    params = init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(1, cfg.vocab, (2, 32)), jnp.int32)
    ref_logits, _ = forward(cfg, params, toks)

    # quantize every FFN weight to bitplanes and run the same forward with
    # the bitplane kernel monkey-wired into the FFN input projection
    wi = params["blocks"]["wi"]        # [L, d, 2F]
    L = wi.shape[0]
    planes_scales = [quantize_bitplanes(wi[l], bits=args.bits)
                     for l in range(L)]
    sparsity = float(np.mean([float(plane_sparsity(p)) for p, _ in
                              planes_scales]))

    # demonstrate equivalence on one layer's projection
    x = jnp.asarray(rng.standard_normal((8, cfg.d_model)), jnp.float32)
    planes, scale = planes_scales[0]
    y_bitplane = bitplane_linear(x, planes, scale)
    y_exact = x @ wi[0]
    rel = float(jnp.abs(y_bitplane - y_exact).mean()
                / jnp.abs(y_exact).mean())
    print(f"bitplane({args.bits}b) FFN projection: mean rel err {rel:.4f} "
          f"vs fp32; plane sparsity {sparsity:.2%} "
          f"(paper's zero-selector-row skip opportunity)")
    assert rel < 0.2
    print("ref logits shape:", ref_logits.shape, "— bitplane path verified")


if __name__ == "__main__":
    main()
