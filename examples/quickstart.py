"""Quickstart: the paper's contribution end-to-end in two minutes.

1. Synthesize an unrolled (constant-weight) DNN layer with the improved
   CAD flow (Wallace compressor trees + shared adder chains).
2. Pack it on the baseline Stratix-10-like architecture and on Double-Duty
   DD5; compare area / critical path / ADP.
3. Validate functional correctness of the synthesized netlist against
   integer arithmetic via the JAX bit-parallel simulator.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import random

import numpy as np

from repro.core.alm import BASELINE, DD5
from repro.core.circuits import kratos_gemm
from repro.core.eval_jax import eval_netlist_jax
from repro.core.netlist import Netlist, bus_to_ints, eval_netlist
from repro.core.packing import pack
from repro.core.synth import synth_dot_const
from repro.core.timing import analyze


def main():
    # --- 1. synthesize a small unrolled GEMM ------------------------------
    net = kratos_gemm("demo-gemm", m=8, n=8, width=6, sparsity=0.5, seed=0)
    st = net.stats()
    print(f"synthesized: {st['luts']} LUTs, {st['adders']} adders "
          f"({st['chains']} carry chains)")

    # --- 2. pack on baseline vs Double-Duty -------------------------------
    rows = {}
    for arch in (BASELINE, DD5):
        r = analyze(pack(net, arch, seed=0))
        rows[arch.name] = r
        print(f"{arch.name:9s}: {r['alms']:5d} ALMs  "
              f"{r['critical_path_ps']:7.0f} ps  "
              f"area {r['area_mwta']/1e6:6.2f} MWTA(M)  "
              f"concurrent LUTs {r['concurrent_luts']}")
    b, d = rows["baseline"], rows["dd5"]
    print(f"Double-Duty: area {100*(1-d['area_mwta']/b['area_mwta']):.1f}% "
          f"smaller, ADP {100*(1-d['adp']/b['adp']):.1f}% better")

    # --- 3. functional validation -----------------------------------------
    rng = random.Random(0)
    demo = Netlist("dot")
    xs = [demo.add_pi_bus(f"x{i}", 6) for i in range(4)]
    ws = [rng.randrange(1, 64) for _ in range(4)]
    out = synth_dot_const(demo, xs, ws, 6, algo="wallace", signed=False)
    demo.set_po_bus("y", out)
    lanes = {}
    xvals = [[rng.getrandbits(6) for _ in range(32)] for _ in xs]
    for bus, vals in zip(xs, xvals):
        for j, s in enumerate(bus):
            lanes[s] = np.array(
                [sum(((vals[v] >> j) & 1) << v for v in range(32))],
                dtype=np.uint32)
    grid = np.asarray(eval_netlist_jax(demo, lanes, 1))
    got = []
    for v in range(32):
        acc = 0
        for j, s in enumerate(out):
            acc |= int((grid[s, 0] >> v) & 1) << j
        got.append(acc)
    want = [sum(x[v] * w for x, w in zip(xvals, ws)) % (1 << len(out))
            for v in range(32)]
    assert got == want, "netlist disagrees with integer dot product!"
    print("functional check: 32/32 random vectors match integer arithmetic")


if __name__ == "__main__":
    main()
