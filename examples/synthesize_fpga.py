"""Paper-experiment walkthrough: synthesize a Kratos-style benchmark with
every reduction algorithm, pack on baseline / DD5 / DD6, and print the
Fig. 5 + Fig. 6-style comparison for one circuit.

Run:  PYTHONPATH=src python examples/synthesize_fpga.py
"""
from repro.core.alm import ARCHS
from repro.core.circuits import kratos_conv1d
from repro.core.packing import pack
from repro.core.timing import analyze
from repro.core.synth import ALGOS


def main():
    print("=== CAD algorithms (baseline arch), conv1d-FU ===")
    base_adp = None
    for algo in ALGOS:
        net = kratos_conv1d(in_ch=2, out_ch=4, width=6, sparsity=0.5,
                            algo=algo, seed=0)
        r = analyze(pack(net, ARCHS["baseline"], seed=0))
        if base_adp is None:
            base_adp = r["adp"]
        print(f"  {algo:13s} adders={net.n_adders:6d} luts={net.n_luts:6d} "
              f"alms={r['alms']:5d} cpd={r['critical_path_ps']:7.0f}ps "
              f"adp={r['adp']/base_adp:5.2f}x")

    print("\n=== Architectures (Wallace synthesis) ===")
    net = kratos_conv1d(in_ch=2, out_ch=4, width=6, sparsity=0.5,
                        algo="wallace", seed=0)
    base = None
    for arch_name in ("baseline", "dd5", "dd6"):
        r = analyze(pack(net, ARCHS[arch_name], seed=0))
        if base is None:
            base = r
        print(f"  {arch_name:9s} alms={r['alms']:5d} "
              f"area={100*r['area_mwta']/base['area_mwta']:6.1f}% "
              f"cpd={100*r['critical_path_ps']/base['critical_path_ps']:6.1f}% "
              f"adp={100*r['adp']/base['adp']:6.1f}% "
              f"concurrent={r['concurrent_luts']}")


if __name__ == "__main__":
    main()
